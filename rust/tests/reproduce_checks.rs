//! Headline-claim integration tests: the reproduce harness must show the
//! paper's qualitative results — who wins, by roughly what factor, where
//! the exceptions are.

use cornstarch::coordinator::experiments;
use cornstarch::model::Size;

/// §6.2: "Cornstarch outperforms the baselines by up to 1.57x, with one
/// exception: VLM-S [at LLM-M]". We accept the win band 1.2x–2.2x.
#[test]
fn headline_speedup_in_band() {
    let mut max_speedup = 0.0f64;
    for s in Size::ALL {
        let (_, rows) = experiments::fig9_13_14(s);
        for r in &rows {
            max_speedup = max_speedup.max(r.speedup_vs_best_baseline());
        }
        let (_, rows) = experiments::fig10_15(s);
        for r in &rows {
            max_speedup = max_speedup.max(r.speedup_vs_best_baseline());
        }
    }
    assert!(
        (1.2..2.2).contains(&max_speedup),
        "max e2e speedup {max_speedup:.2} out of paper band (paper: 1.57x)"
    );
}

/// §6.2.2 VALM-MM at LLM-M: the paper reports 1.44x from frozen-aware
/// modality parallelism with stage ranges shrinking.
#[test]
fn valm_mm_stage_balance_improves() {
    use cornstarch::cost::Device;
    use cornstarch::modality::{planner, MultimodalModule, MultimodalParallelSpec, Strategy};
    use cornstarch::model::MllmSpec;
    let spec = MllmSpec::valm(Size::M, Size::M, Size::M);
    let mm = MultimodalModule::from_spec(&spec);
    // Table 6 configs: colocated (3,4), cornstarch (4,1,1)
    let col = planner::plan(
        Strategy::Colocated,
        &mm,
        &MultimodalParallelSpec::paper_default(&[4, 4], 3, 2, 2),
        Device::a40(),
    );
    let cs = planner::plan(
        Strategy::Cornstarch,
        &mm,
        &MultimodalParallelSpec::paper_default(&[1, 1], 4, 2, 2),
        Device::a40(),
    );
    let (col_lo, col_hi) = col.stage_time_range();
    let (cs_lo, cs_hi) = cs.stage_time_range();
    assert!(
        cs_hi / cs_lo < col_hi / col_lo,
        "cornstarch range {cs_lo:.0}~{cs_hi:.0} not tighter than \
         colocated {col_lo:.0}~{col_hi:.0}"
    );
    let m_col = col.simulate();
    let m_cs = cs.simulate();
    let speedup = m_cs.throughput_per_gpu / m_col.throughput_per_gpu;
    assert!(
        (1.0..2.0).contains(&speedup),
        "VALM-MM speedup {speedup:.2} (paper: 1.44x)"
    );
}

/// §6.4: frozen-aware partitioning helps most where encoders are large
/// (paper headline: VLM-L 1.53x). ALM-S is the paper's no-change case.
#[test]
fn frozen_awareness_gains_track_paper() {
    let (_, rows) = experiments::table3_10_11(Size::M);
    let gain = |model: &str| {
        let a = rows
            .iter()
            .find(|r| r.model == model && r.aware)
            .unwrap()
            .tput_per_gpu;
        let u = rows
            .iter()
            .find(|r| r.model == model && !r.aware)
            .unwrap()
            .tput_per_gpu;
        a / u
    };
    let vlm_l = gain("VLM-L");
    assert!(
        (1.15..2.0).contains(&vlm_l),
        "VLM-L frozen-aware gain {vlm_l:.2} (paper: 1.53x)"
    );
    // ALM-S: paper shows identical configs -> no gain.
    let alm_s = gain("ALM-S");
    assert!(
        (0.95..1.1).contains(&alm_s),
        "ALM-S should be ~neutral, got {alm_s:.2}"
    );
    // Aware never loses badly anywhere.
    for m in ["VLM-S", "VLM-M", "VLM-L", "ALM-S", "ALM-M", "ALM-L"] {
        let g = gain(m);
        assert!(g > 0.85, "{m}: aware/unaware {g:.2}");
    }
}

/// §6.5 / Table 4: LPT and Random beat naive ring and zigzag on EE and MP
/// masks; all roughly tie on EP (simple mask). Crossover check: on EP the
/// zigzag gap must be small (<10%), on EE/MP large (>10%) at 64k.
#[test]
fn cp_crossover_matches_paper() {
    let (_, rows) = experiments::table4(30);
    let get = |len: usize, mt: experiments::MaskType, alg: &str| {
        rows.iter()
            .find(|(l, m, a, _)| *l == len && *m == mt && a == alg)
            .unwrap()
            .3
    };
    for len in [16384usize, 65536] {
        // EP: all algorithms within ~12% of LPT (paper: 3.92..4.24)
        let lpt = get(len, experiments::MaskType::Ep, "LPT");
        let zz = get(len, experiments::MaskType::Ep, "Zigzag");
        assert!(
            zz / lpt < 1.35,
            "{len}/EP zigzag {zz:.2} vs LPT {lpt:.2} — should be close"
        );
    }
    // EE + MP at 64k: ring clearly worse than LPT (paper: 46.67 vs 36.99)
    let lpt = get(65536, experiments::MaskType::Ee, "LPT");
    let ring = get(65536, experiments::MaskType::Ee, "Naive Ring");
    assert!(
        ring / lpt > 1.05,
        "64k/EE ring {ring:.2} vs LPT {lpt:.2} — paper gap ~1.26x"
    );
    // Random ~ LPT everywhere (paper: within noise)
    for mt in experiments::MaskType::ALL {
        let l = get(65536, mt, "LPT");
        let r = get(65536, mt, "Random");
        assert!(
            (r / l - 1.0).abs() < 0.15,
            "64k/{:?} random {r:.2} vs LPT {l:.2}",
            mt
        );
    }
}

/// Figure 2's caption: encoders-replicated takes ~1.57x longer than the
/// non-redundant policies.
#[test]
fn fig2_replication_overhead() {
    let (_, rows) = experiments::fig2();
    let cs = rows[0].1;
    let rep = rows[2].1;
    assert!(rep / cs > 1.3, "replicated/cornstarch {:.2}", rep / cs);
}
