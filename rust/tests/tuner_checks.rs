//! Tuner acceptance + CP solver parity.
//!
//! * property tests: `cp::exact` (branch-and-bound, the §4.3.2 ILP) never
//!   yields a worse max-workload than greedy LPT, and respects the
//!   packing lower bounds — seeded through `util::rng` so failures
//!   reproduce;
//! * regression: the tuner's plan cache round-trips through disk and a
//!   second query returns the identical best plan without re-simulating;
//! * acceptance: `tune VLM-M --devices 16` end-to-end beats the best of
//!   the three fixed planners on the same scenario;
//! * capacity: the default space never offers the simulator a candidate
//!   whose modeled peak memory exceeds the A40 budget, and the cached
//!   top-k frontier serves ranked alternatives without re-searching.

use cornstarch::api::ClusterSpec;
use cornstarch::cost::Device;
use cornstarch::cp::{exact_min_makespan, makespan, Algorithm};
use cornstarch::modality::{
    planner, MultimodalModule, MultimodalParallelSpec, Strategy,
};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::tuner::{
    build_plan, enumerate, tune, SearchSpace, TuneRequest,
};
use cornstarch::util::check::check;
use cornstarch::util::rng::Rng;

#[test]
fn exact_never_worse_than_lpt_on_small_instances() {
    check("exact <= LPT makespan", 60, |g| {
        let b = g.usize(1, 15);
        let w: Vec<u64> = (0..b).map(|_| g.rng.below(120) + 1).collect();
        let ranks = g.usize(1, 6);
        let opt = exact_min_makespan(&w, ranks);
        let lpt = makespan(&w, &Algorithm::Lpt.assign(&w, ranks), ranks);
        assert!(opt <= lpt, "exact {opt} > LPT {lpt} on {w:?} / {ranks}");
        // and exact respects both packing lower bounds
        let total: u64 = w.iter().sum();
        assert!(opt >= total.div_ceil(ranks as u64));
        assert!(opt >= w.iter().copied().max().unwrap_or(0));
    });
}

/// Hold every CP distribution to the branch-and-bound exact oracle on
/// all three multimodal mask generators — EP and MP included, which the
/// EE-only tests used to leave uncovered: no heuristic may beat the ILP
/// optimum, and greedy LPT must stay within the paper's imbalance bound
/// (Graham's (4/3 − 1/(3G))·OPT) on every generator.
#[test]
fn cp_heuristics_respect_the_exact_oracle_on_all_mask_types() {
    use cornstarch::bam::{self, Bam};

    type Generator = fn(&mut Rng, usize) -> Bam;
    let generators: [(&str, Generator); 3] = [
        ("EP", |rng, t| bam::generators::random_ep(rng, t, 3)),
        ("EE", |rng, t| bam::generators::random_ee(rng, t, 3)),
        ("MP", |rng, t| bam::generators::random_mp(rng, t)),
    ];
    for (name, generate) in generators {
        check(&format!("{name} masks vs exact oracle"), 12, |g| {
            // Small instances keep branch-and-bound tractable: ~12
            // blocks of 128 tokens over 2..4 ranks.
            let t = 128 * g.usize(8, 13);
            let ranks = g.usize(2, 5);
            let mask = generate(&mut g.rng, t);
            let w = bam::block_workloads(&mask.workloads(), 128);
            let opt = exact_min_makespan(&w, ranks);
            for alg in [
                Algorithm::Lpt,
                Algorithm::Zigzag,
                Algorithm::Ring,
                Algorithm::Random { seed: g.seed },
            ] {
                let mk = makespan(&w, &alg.assign(&w, ranks), ranks);
                assert!(
                    mk >= opt,
                    "{name}: {} makespan {mk} beat the exact {opt}",
                    alg.name()
                );
            }
            let lpt = makespan(&w, &Algorithm::Lpt.assign(&w, ranks), ranks);
            let bound =
                (4.0 / 3.0 - 1.0 / (3.0 * ranks as f64)) * opt as f64;
            assert!(
                lpt as f64 <= bound + 1e-9,
                "{name}: LPT {lpt} above Graham bound {bound:.1} (OPT {opt})"
            );
        });
    }
}

#[test]
fn exact_matches_lpt_when_lpt_is_provably_optimal() {
    // Uniform workloads in multiples of the rank count: LPT achieves the
    // mean exactly, so exact must equal it.
    let mut rng = Rng::new(0x5EED);
    for _ in 0..20 {
        let ranks = 2 + (rng.below(4) as usize);
        let per = 1 + rng.below(40);
        let w = vec![per; ranks * (1 + rng.below(3) as usize)];
        let opt = exact_min_makespan(&w, ranks);
        let lpt = makespan(&w, &Algorithm::Lpt.assign(&w, ranks), ranks);
        assert_eq!(opt, lpt);
        assert_eq!(opt, per * (w.len() / ranks) as u64);
    }
}

fn acceptance_request(cache: Option<String>) -> TuneRequest {
    let mut req = TuneRequest::new(MllmSpec::vlm(Size::M, Size::M), 16);
    req.threads = 2;
    req.cache_path = cache;
    req
}

/// The ISSUE's acceptance scenario: tune VLM-M on 16 devices; the result
/// must be at least as fast as the best of the three baseline planners on
/// the same scenario (tp=2, cp=2, 24 microbatches, 4 device groups).
#[test]
fn tuned_vlm_m_16_devices_beats_all_baseline_planners() {
    let out = tune(&acceptance_request(None)).unwrap();
    assert!(!out.cache_hit);
    let spec = MllmSpec::vlm(Size::M, Size::M);
    let mm = MultimodalModule::from_spec(&spec);
    let d = Device::a40();
    let mut best_baseline = f64::INFINITY;
    for (strategy, enc_pp, llm_pp) in [
        (Strategy::Cornstarch, vec![1usize], 3usize),
        (Strategy::Colocated, vec![1], 3),
        (Strategy::Replicated, vec![], 4),
    ] {
        let ps = MultimodalParallelSpec::paper_default(&enc_pp, llm_pp, 2, 2);
        let m = planner::plan(strategy, &mm, &ps, d).simulate();
        best_baseline = best_baseline.min(m.iteration_ms);
    }
    assert!(
        out.entry.best().iteration_ms <= best_baseline + 1e-9,
        "tuned {:.1} ms vs best baseline {:.1} ms",
        out.entry.best().iteration_ms,
        best_baseline
    );
    // The winner must fit the GPU budget, the A40 memory budget, and be
    // executable.
    assert!(out.entry.best().n_gpus <= 16);
    assert!(
        out.entry.best().peak_mem_bytes
            <= ClusterSpec::a40_default().mem_budget_bytes()
    );
    let plan = out.instantiate(&spec, &ClusterSpec::a40_default());
    let m = plan.simulate();
    assert!((m.iteration_ms - out.entry.best().iteration_ms).abs() < 1e-6);
}

/// The ISSUE's capacity acceptance: with the default space, the tuner
/// never simulates a candidate whose modeled peak exceeds the device
/// budget — enumeration is the gate, so every enumerated candidate (the
/// only ones the search can ever hand to the simulator) must fit.
#[test]
fn default_space_only_offers_memory_feasible_candidates() {
    let spec = MllmSpec::vlm(Size::M, Size::M);
    let mm = MultimodalModule::from_spec(&spec);
    let space = SearchSpace::paper_default(16);
    let budget = space
        .memory_budget_bytes
        .expect("default space carries the A40 budget");
    let cands = enumerate(&mm, &space);
    assert!(!cands.is_empty());
    for c in &cands {
        let plan = build_plan(&spec, c, &ClusterSpec::a40_default());
        assert!(
            plan.peak_device_bytes() <= budget,
            "OOM candidate would be simulated: {}",
            c.label()
        );
    }
}

/// Top-k frontier acceptance: one search answers later "trade throughput
/// for fewer GPUs / more headroom" queries straight from the cache.
#[test]
fn cached_frontier_offers_ranked_alternatives() {
    let mut path = std::env::temp_dir();
    path.push(format!(
        "cornstarch-tuner-frontier-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let mut req =
        acceptance_request(Some(path.to_string_lossy().into_owned()));
    req.top = 4;
    let first = tune(&req).unwrap();
    assert!(!first.cache_hit);
    assert!(first.entry.frontier.len() > 1, "frontier collapsed");
    let second = tune(&req).unwrap();
    assert!(second.cache_hit);
    assert_eq!(first.entry, second.entry);
    // ranked, and every alternative is memory-feasible
    let f = &second.entry.frontier;
    assert!(f
        .windows(2)
        .all(|w| w[0].iteration_ms <= w[1].iteration_ms + 1e-12));
    let budget = ClusterSpec::a40_default().mem_budget_bytes();
    assert!(f.iter().all(|p| p.peak_mem_bytes <= budget));
    let _ = std::fs::remove_file(&path);
}

/// Cache regression: serialize → load → identical best plan, with zero
/// re-simulation on the second query.
#[test]
fn tuner_cache_roundtrip_returns_identical_plan() {
    let mut path = std::env::temp_dir();
    path.push(format!(
        "cornstarch-tuner-accept-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let cache = Some(path.to_string_lossy().into_owned());

    let first = tune(&acceptance_request(cache.clone())).unwrap();
    assert!(!first.cache_hit);
    assert!(first.evaluated > 0);

    let second = tune(&acceptance_request(cache)).unwrap();
    assert!(second.cache_hit, "second invocation must hit the cache");
    assert_eq!(second.evaluated, 0, "cache hit must not re-simulate");
    assert_eq!(first.entry, second.entry, "cached plan differs");

    // The cached candidate instantiates to the same simulated makespan.
    let spec = MllmSpec::vlm(Size::M, Size::M);
    let plan = second.instantiate(&spec, &ClusterSpec::a40_default());
    assert!(
        (plan.simulate().iteration_ms - first.entry.best().iteration_ms)
            .abs()
            < 1e-6
    );
    let _ = std::fs::remove_file(&path);
}

/// A different query (other budget/devices) never answers from the same
/// cache slot.
#[test]
fn cache_does_not_cross_scenarios() {
    let mut path = std::env::temp_dir();
    path.push(format!(
        "cornstarch-tuner-cross-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let cache = Some(path.to_string_lossy().into_owned());

    let a = tune(&acceptance_request(cache.clone())).unwrap();
    let mut req8 = TuneRequest::new(MllmSpec::vlm(Size::M, Size::M), 8);
    req8.threads = 2;
    req8.cache_path = cache;
    let b = tune(&req8).unwrap();
    assert!(!b.cache_hit, "8-device query must not reuse the 16-device plan");
    assert!(b.entry.best().n_gpus <= 8);
    assert!(a.entry.best().n_gpus <= 16);
    let _ = std::fs::remove_file(&path);
}
