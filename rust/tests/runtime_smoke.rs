//! End-to-end smoke: load the tiny model's artifacts, run encoder ->
//! projector -> llm stages -> head through PJRT, check the loss is finite.
//!
//! Needs `make artifacts` first — gated behind the `artifacts` feature so
//! a clean checkout passes `cargo test` (run with
//! `cargo test --features artifacts` once artifacts are built).
#![cfg(feature = "artifacts")]

use cornstarch::runtime::{HostTensor, Manifest, ModelRuntime, Role};

fn artifacts_root() -> std::path::PathBuf {
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}

#[test]
fn tiny_forward_chain_produces_finite_loss() {
    let manifest = Manifest::load(artifacts_root()).unwrap();
    let mut rt = ModelRuntime::load_all(&manifest, "tiny").unwrap();
    let m = rt.model().clone();
    assert_eq!(rt.platform(), "cpu");

    // encoder input: deterministic pseudo-data
    let enc_in = rt.artifact("enc:vision", Role::Fwd).unwrap().ins[1].clone();
    let n = enc_in.elements();
    let x: Vec<f32> = (0..n).map(|i| ((i % 13) as f32) * 0.01).collect();
    let feats = rt
        .execute("enc:vision", Role::Fwd, &[HostTensor::f32(&enc_in.dims, x)])
        .unwrap()
        .remove(0);
    let mod_h = rt.execute("proj:vision", Role::Fwd, &[feats]).unwrap().remove(0);

    let bits: Vec<i32> = m.bam_bits().iter().map(|&b| b as i32).collect();
    let pos: Vec<i32> = (0..m.total_tokens as i32).collect();
    let text_ids: Vec<i32> = (0..m.text_len as i32).map(|i| i % m.vocab as i32).collect();
    let mut h = rt
        .execute(
            "llm:0",
            Role::Fwd,
            &[
                HostTensor::i32(&[m.text_len], text_ids),
                mod_h,
                HostTensor::i32(&[m.total_tokens], bits.clone()),
                HostTensor::i32(&[m.total_tokens], pos.clone()),
            ],
        )
        .unwrap()
        .remove(0);
    for s in 1..m.n_llm_stages() {
        h = rt
            .execute(
                &format!("llm:{s}"),
                Role::Fwd,
                &[
                    h,
                    HostTensor::i32(&[m.total_tokens], bits.clone()),
                    HostTensor::i32(&[m.total_tokens], pos.clone()),
                ],
            )
            .unwrap()
            .remove(0);
    }
    let labels: Vec<i32> = (0..m.total_tokens as i32).map(|i| i % m.vocab as i32).collect();
    let loss = rt
        .execute(
            "llm:head",
            Role::Fwd,
            &[h, HostTensor::i32(&[m.total_tokens], labels)],
        )
        .unwrap()
        .remove(0)
        .scalar()
        .unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    // random init over vocab 512: loss should be near ln(512) ~ 6.24
    assert!((2.0..12.0).contains(&loss), "loss {loss}");
}
