//! Acceptance and invariant tests for the fleet-planning layer and
//! `PlanDiff`:
//!
//! * the ISSUE criterion — `reproduce fleet` shows the searched
//!   two-tenant carve of the 4×A40 + 4×A100-80G pool strictly beating
//!   the naive static halving on aggregate simulated throughput, and the
//!   per-tenant diff between the two allocations is a stable non-empty
//!   delta;
//! * partition invariants — every enumerated carve respects per-group
//!   GPU counts and assigns no device to two tenants;
//! * the golden-file guarantee — `PlanDiff` of a plan against itself is
//!   empty and renders exactly the committed fixture.

use cornstarch::api::{
    enumerate_partitions, ClusterSpec, FleetPartition, FleetRequest,
    PlanDiff, PlanRequest, PlanningService,
};
use cornstarch::coordinator::experiments;
use cornstarch::model::{MllmSpec, Size};

/// The committed rendering of an empty diff — byte-for-byte.
const EMPTY_DIFF_GOLDEN: &str = include_str!("golden/plan_diff_empty.txt");

#[test]
fn every_carve_respects_group_counts_and_never_double_assigns() {
    for (cluster, tenants) in [
        (ClusterSpec::a40_a100_demo(), 2usize),
        (ClusterSpec::a40_a100_demo(), 3),
        (ClusterSpec::a40_default().with_devices(6), 2),
    ] {
        let parts = enumerate_partitions(&cluster, tenants);
        assert!(!parts.is_empty());
        for p in &parts {
            assert_eq!(p.slices.len(), tenants);
            assert!(p.respects(&cluster), "{}", p.label());
            for (g, grp) in cluster.groups.iter().enumerate() {
                let assigned: usize =
                    p.slices.iter().map(|s| s[g]).sum();
                // every device handed out exactly once: the per-group sum
                // matches the group's count, so none is double-assigned
                // and none is silently dropped
                assert_eq!(assigned, grp.count, "{}", p.label());
            }
        }
        // no carve repeats
        for (i, p) in parts.iter().enumerate() {
            assert!(!parts[..i].contains(p), "duplicate carve {}", p.label());
        }
    }
}

#[test]
fn subpools_of_a_carve_never_overlap() {
    let cluster = ClusterSpec::a40_a100_demo();
    for p in enumerate_partitions(&cluster, 2) {
        let mut used = vec![0usize; cluster.groups.len()];
        for (t, slice) in p.slices.iter().enumerate() {
            if let Some(sub) = p.subpool(&cluster, t, "t") {
                assert!(sub.validate().is_ok(), "{}", p.label());
                assert_eq!(
                    sub.devices(),
                    slice.iter().sum::<usize>(),
                    "{}",
                    p.label()
                );
            }
            for (g, &c) in slice.iter().enumerate() {
                used[g] += c;
            }
        }
        for (g, grp) in cluster.groups.iter().enumerate() {
            assert!(used[g] <= grp.count, "{}", p.label());
        }
    }
}

#[test]
fn self_diff_is_empty_and_matches_the_golden_file() {
    let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S))
        .devices(8)
        .threads(2);
    let report = PlanningService::new().plan(&req).unwrap();
    let diff = PlanDiff::between(&report, &report);
    assert!(diff.is_empty(), "a plan diffed against itself must be empty");
    assert_eq!(diff.render(), EMPTY_DIFF_GOLDEN);
}

/// The ISSUE's acceptance criterion, end to end. One call produces both
/// allocations (searched + naive) and the rendered per-tenant delta.
#[test]
fn reproduce_fleet_beats_naive_halving_and_diffs_the_allocations() {
    let (table, row) = experiments::fleet_planning();

    // strictly better aggregate simulated throughput than the halving
    assert!(
        row.searched_tput > row.naive_tput,
        "searched carve {:.3} input/s must strictly beat the naive \
         halving {:.3} input/s",
        row.searched_tput,
        row.naive_tput
    );

    // the chosen carve is an exact, non-overlapping split of 4 + 4
    assert_eq!(row.partition.len(), 2);
    let cluster = ClusterSpec::a40_a100_demo();
    for (g, grp) in cluster.groups.iter().enumerate() {
        let assigned: usize =
            row.partition.iter().map(|s| s[g]).sum();
        assert_eq!(assigned, grp.count, "partition {:?}", row.partition);
    }
    // ...and it is NOT the halving (otherwise the strict win above is
    // impossible anyway; this names the failure more directly)
    assert_ne!(
        row.partition,
        vec![vec![2, 2], vec![2, 2]],
        "searched carve collapsed to the naive halving"
    );

    // the diff between the two fleet allocations is non-empty and stable
    assert!(!row.diff.is_empty());
    assert!(row.diff.contains("tenant "), "{}", row.diff);
    assert!(row.diff.contains("->"), "{}", row.diff);
    // at least one tenant's cluster fingerprint changed: the carve moved
    // devices between tenants
    assert!(row.diff.contains("cluster:"), "{}", row.diff);
    // deterministic: a second run renders the identical delta
    let (_, row2) = experiments::fleet_planning();
    assert_eq!(row.diff, row2.diff);
    assert_eq!(row.partition, row2.partition);

    // the rendered table names both allocations
    let text = table.render();
    assert!(text.contains("naive aggregate"), "{text}");
    assert!(text.contains("searched aggregate"), "{text}");
    assert!(text.contains("improvement"), "{text}");
}

#[test]
fn fleet_reports_honor_their_own_fairness_floor() {
    // Small homogeneous pool so the test stays cheap: the searched carve
    // must keep every tenant at or above the floor it was asked for.
    let req = FleetRequest::new(ClusterSpec::a40_default().with_devices(4))
        .tenant(
            "a",
            PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S))
                .threads(2),
        )
        .tenant(
            "b",
            PlanRequest::default_for(MllmSpec::alm(Size::S, Size::S))
                .threads(2),
        )
        .fairness_floor(0.2);
    let report = PlanningService::new().plan_fleet(&req).unwrap();
    for t in &report.tenants {
        assert!(
            t.fairness() >= 0.2,
            "tenant {} at {:.2}x solo breaks the 0.2 floor",
            t.name,
            t.fairness()
        );
        assert!(t.report.fits_budget(), "tenant {} over budget", t.name);
    }
    // the naive split of the same request evaluates without the floor
    let naive = PlanningService::new()
        .plan_fleet_partition(&req, &req.naive_partition())
        .unwrap();
    assert!(
        report.aggregate_throughput >= naive.aggregate_throughput - 1e-9
    );
    // both carves assign all 4 devices
    for rep in [&report, &naive] {
        let total: usize = rep
            .partition
            .slices
            .iter()
            .map(|s| s.iter().sum::<usize>())
            .sum();
        assert_eq!(total, 4);
    }
}

#[test]
fn naive_partition_is_the_even_split_of_every_group() {
    let freq = FleetRequest::new(ClusterSpec::a40_a100_demo())
        .tenant(
            "a",
            PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S)),
        )
        .tenant(
            "b",
            PlanRequest::default_for(MllmSpec::alm(Size::S, Size::S)),
        );
    assert_eq!(
        freq.naive_partition(),
        FleetPartition { slices: vec![vec![2, 2], vec![2, 2]] }
    );
}
