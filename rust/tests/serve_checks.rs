//! Planning-as-a-service checks: the two-tier plan store, the in-flight
//! dedupe table, and the `cornstarch serve` protocol under concurrency.
//!
//! Five properties the long-lived service depends on:
//!   1. N threads hammering one cache file with mixed hits and misses
//!      lose no entries — every workload's plan survives to disk.
//!   2. K identical concurrent requests coalesce onto exactly one
//!      search (pinned via telemetry: `evaluated` counted once,
//!      `cache_miss` == 1, `cache_hit` == K-1).
//!   3. A served report is byte-identical to what a one-shot `plan()`
//!      renders for the same request — the wire adds nothing.
//!   4. A served *fleet* report is byte-identical to a one-shot
//!      `plan_fleet()` on the request the same line builds.
//!   5. K identical concurrent fleet requests coalesce per sub-pool
//!      signature: one fleet's worth of search, `cache_miss` unchanged
//!      from the cold baseline, `cache_hit` == (K-1) × misses.

use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;

use cornstarch::api::{ClusterSpec, PlanRequest, PlanningService};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::serve::{
    build_fleet_request, respond_line, ServeOpts, Server,
};
use cornstarch::telemetry::{key as tkey, Scope};
use cornstarch::tuner::PlanCache;
use cornstarch::util::json::Json;

fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "cornstarch-serve-checks-{tag}-{}.json",
        std::process::id()
    ))
}

/// A small request whose `budget` doubles as the workload's identity:
/// distinct budgets yield distinct cache signatures.
fn small_request(budget: usize) -> PlanRequest {
    PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S))
        .devices(8)
        .budget(budget)
        .threads(1)
}

#[test]
fn concurrent_mixed_hit_miss_loses_no_entries() {
    let path = temp_path("mixed");
    let _ = std::fs::remove_file(&path);
    let cache = path.to_string_lossy().into_owned();
    const THREADS: usize = 8;
    const SHARED_BUDGET: usize = 49;

    // Warm the shared workload so every thread's first request mixes a
    // hit in with its own unique miss.
    PlanningService::new()
        .plan(&small_request(SHARED_BUDGET).cache_file(&cache))
        .expect("warm shared workload");

    std::thread::scope(|scope| {
        for i in 0..THREADS {
            let cache = &cache;
            scope.spawn(move || {
                let service = PlanningService::new();
                let hit = service
                    .plan(&small_request(SHARED_BUDGET).cache_file(cache))
                    .expect("shared workload");
                assert!(hit.provenance.cache_hit, "shared must stay warm");
                let miss = service
                    .plan(&small_request(50 + i).cache_file(cache))
                    .expect("unique workload");
                assert!(!miss.provenance.cache_hit, "budget {} is unique", 50 + i);
            });
        }
    });

    // Every workload is answerable warm...
    let service = PlanningService::new();
    for budget in
        std::iter::once(SHARED_BUDGET).chain((0..THREADS).map(|i| 50 + i))
    {
        let again = service
            .plan(&small_request(budget).cache_file(&cache))
            .expect("replan");
        assert!(again.provenance.cache_hit, "lost budget={budget}");
    }
    // ...and every entry made it to disk despite the concurrent,
    // batched writers (1 shared + THREADS unique).
    let on_disk = PlanCache::load(&path);
    assert_eq!(on_disk.len(), THREADS + 1, "entries lost on disk");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn identical_concurrent_requests_coalesce_onto_one_search() {
    const K: usize = 8;
    // A budget nothing else in this binary uses: the process-wide
    // memory store must see this signature for the first time here.
    let req = small_request(7777).cache_memory();

    let scope_counters = Scope::new();
    let reports: Vec<_> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..K)
            .map(|_| {
                let req = req.clone();
                let counters = scope_counters.clone();
                scope.spawn(move || {
                    let _guard = counters.attach();
                    PlanningService::new().plan(&req).expect("plan")
                })
            })
            .collect();
        workers.into_iter().map(|w| w.join().expect("worker")).collect()
    });

    let misses: Vec<_> =
        reports.iter().filter(|r| !r.provenance.cache_hit).collect();
    assert_eq!(misses.len(), 1, "exactly one request may search");
    let leader = misses[0];
    assert!(leader.provenance.stats.evaluated > 0);

    // Everyone agrees on the answer.
    let winner = leader.winner().candidate.label();
    for r in &reports {
        assert_eq!(r.winner().candidate.label(), winner);
        if r.provenance.cache_hit {
            assert_eq!(
                r.provenance.stats.evaluated, 0,
                "a hit/join must not have searched"
            );
        }
    }

    // The shared scope saw the whole fan-in: one search's worth of
    // simulation, one miss, K-1 hits (joins or warm map reads).
    let totals = scope_counters.snapshot();
    assert_eq!(
        totals.get(tkey::EVALUATED),
        leader.provenance.stats.evaluated,
        "candidates were simulated more than once"
    );
    assert_eq!(totals.get(tkey::CACHE_MISS), 1);
    assert_eq!(totals.get(tkey::CACHE_HIT), (K - 1) as u64);
    assert_eq!(
        totals.get(tkey::CACHE_MEM_HIT) + totals.get(tkey::INFLIGHT_JOIN),
        (K - 1) as u64,
        "every hit is either a map read or an in-flight join"
    );
}

#[test]
fn served_report_is_byte_identical_to_one_shot_plan() {
    // Unique signature for this test; both sides go through the same
    // process-wide memory store, so compare warm hit against warm hit
    // (a miss and a hit legitimately render different search stats).
    let req = small_request(4321).threads(2).cache_memory();
    let service = PlanningService::new();
    service.plan(&req).expect("cold fill");
    let warm = service.plan(&req).expect("warm one-shot");
    assert!(warm.provenance.cache_hit);

    let server =
        Server::bind("127.0.0.1:0", ServeOpts::default()).expect("bind");
    let addr = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run().expect("serve"));

    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut reader =
        BufReader::new(stream.try_clone().expect("clone stream"));
    stream
        .write_all(
            b"{\"mllm\":\"VLM-S\",\"llm\":\"S\",\"devices\":8,\
              \"budget\":4321,\"threads\":2}\n",
        )
        .expect("send");
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("recv");
    handle.shutdown();
    runner.join().expect("server thread");

    let j = Json::parse(resp.trim()).expect("response is JSON");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(j.get("cache_hit").and_then(Json::as_bool), Some(true));
    assert_eq!(
        j.get("signature").and_then(Json::as_str),
        Some(warm.provenance.signature.as_str())
    );
    let served = j
        .get("report")
        .and_then(Json::as_str)
        .expect("report field");
    assert_eq!(
        served,
        warm.render(),
        "the wire must add nothing to (or lose nothing from) the report"
    );
}

fn fleet_opts() -> ServeOpts {
    ServeOpts {
        cluster: ClusterSpec::a40_default().with_devices(8),
        ..ServeOpts::default()
    }
}

#[test]
fn served_fleet_report_is_byte_identical_to_one_shot_plan_fleet() {
    // Unique budget for this test's sub-pool signatures; the serve path
    // and the one-shot path share the process-wide memory store, so
    // compare warm against warm (a cold fleet call legitimately renders
    // different search stats).
    let line = r#"{"tenants":["VLM-S","ALM-S"],"llm":"S","floor":0.0,
        "budget":9921,"threads":1}"#;
    let opts = fleet_opts();
    let cold = respond_line(line, &opts);
    assert_eq!(
        Json::parse(&cold).unwrap().get("ok").and_then(Json::as_bool),
        Some(true),
        "cold fill failed: {cold}"
    );

    let freq = build_fleet_request(line, &opts).expect("same request");
    let warm = PlanningService::new()
        .plan_fleet(&freq)
        .expect("warm one-shot");

    let resp = respond_line(line, &opts);
    let j = Json::parse(&resp).expect("response is JSON");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(j.get("fleet").and_then(Json::as_bool), Some(true));
    assert_eq!(
        j.get("carve").and_then(Json::as_str),
        Some(warm.partition.label().as_str())
    );
    assert_eq!(
        j.get("search_mode").and_then(Json::as_str),
        Some(warm.provenance.search_mode.name())
    );
    let served = j
        .get("report")
        .and_then(Json::as_str)
        .expect("report field");
    assert_eq!(
        served,
        warm.render(),
        "a served fleet report must match the one-shot rendering"
    );
}

#[test]
fn identical_concurrent_fleet_requests_coalesce_per_subpool() {
    const K: usize = 4;
    let opts = fleet_opts();

    // Cold baseline on its own unique budget: how much search and how
    // many store misses one fleet call costs on this pool.
    let baseline = Scope::new();
    {
        let _guard = baseline.attach();
        let resp = respond_line(
            r#"{"tenants":["VLM-S","ALM-S"],"llm":"S","floor":0.0,
                "budget":9911,"threads":1}"#,
            &opts,
        );
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    }
    let cold = baseline.snapshot();
    let one_fleet_evaluated = cold.get(tkey::EVALUATED);
    let one_fleet_misses = cold.get(tkey::CACHE_MISS);
    assert!(one_fleet_evaluated > 0 && one_fleet_misses > 0);

    // K identical concurrent fleet lines on a second unique budget.
    let line = r#"{"tenants":["VLM-S","ALM-S"],"llm":"S","floor":0.0,
        "budget":9912,"threads":1}"#;
    let counters = Scope::new();
    let carves: Vec<String> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..K)
            .map(|_| {
                let counters = counters.clone();
                let opts = opts.clone();
                scope.spawn(move || {
                    let _guard = counters.attach();
                    let resp = respond_line(line, &opts);
                    let j = Json::parse(&resp).expect("JSON response");
                    assert_eq!(
                        j.get("ok").and_then(Json::as_bool),
                        Some(true),
                        "{resp}"
                    );
                    j.get("carve")
                        .and_then(Json::as_str)
                        .expect("carve field")
                        .to_string()
                })
            })
            .collect();
        workers.into_iter().map(|w| w.join().expect("worker")).collect()
    });
    for carve in &carves {
        assert_eq!(carve, &carves[0], "all requests agree on the carve");
    }

    // One fleet's worth of search total; every repeated sub-pool query
    // either joined the in-flight search or hit the warm map.
    let totals = counters.snapshot();
    assert_eq!(
        totals.get(tkey::EVALUATED),
        one_fleet_evaluated,
        "sub-pool searches were not coalesced"
    );
    assert_eq!(totals.get(tkey::CACHE_MISS), one_fleet_misses);
    assert_eq!(
        totals.get(tkey::CACHE_HIT),
        (K as u64 - 1) * one_fleet_misses,
        "every repeat of a missed signature must come back as a hit"
    );
}
