//! End-to-end training integration: the full three-layer stack on the
//! `mini` (~35M class) model — artifacts compiled from JAX+Pallas, loaded
//! and driven entirely from rust, loss decreasing, frozen semantics held.
//!
//! Needs `make artifacts` first — gated behind the `artifacts` feature so
//! a clean checkout passes `cargo test` (run with
//! `cargo test --features artifacts` once artifacts are built).
#![cfg(feature = "artifacts")]

use cornstarch::runtime::{Manifest, Role};
use cornstarch::train::{
    FrozenPolicy, PipelineTrainer, SyntheticDataset, Trainer,
};

fn artifacts_root() -> std::path::PathBuf {
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}

#[test]
fn mini_model_loss_decreases_in_pipeline_executor() {
    let manifest = Manifest::load(artifacts_root()).unwrap();
    let mut pipe =
        PipelineTrainer::new(&manifest, "mini", FrozenPolicy::paper(), 2e-3)
            .unwrap();
    let model = manifest.model("mini").unwrap().clone();
    let ds = SyntheticDataset::new(&model, 123);
    let batch: Vec<_> = (0..2).map(|i| ds.sample(i)).collect();
    let first = pipe.train_step(&batch).unwrap();
    let mut last = first.clone();
    for _ in 0..5 {
        last = pipe.train_step(&batch).unwrap();
    }
    assert!(
        last.loss < first.loss,
        "mini loss did not decrease: {} -> {}",
        first.loss,
        last.loss
    );
}

#[test]
fn optimizer_state_matches_between_executors_after_steps() {
    // After identical steps, parameters must agree across executors (the
    // pipeline executor applies AdamW per stage thread; the single-process
    // one centrally — same artifacts, same update order per component).
    let manifest = Manifest::load(artifacts_root()).unwrap();
    let policy = FrozenPolicy::paper();
    let mut single = Trainer::new(&manifest, "tiny", policy, 5e-3).unwrap();
    let mut pipe =
        PipelineTrainer::new(&manifest, "tiny", policy, 5e-3).unwrap();
    let model = manifest.model("tiny").unwrap().clone();
    let ds = SyntheticDataset::new(&model, 31);
    let batch: Vec<_> = (0..2).map(|i| ds.sample(i)).collect();
    let mut s_loss = Vec::new();
    let mut p_loss = Vec::new();
    for _ in 0..4 {
        s_loss.push(single.train_step(&batch).unwrap().loss);
        p_loss.push(pipe.train_step(&batch).unwrap().loss);
    }
    assert_eq!(s_loss, p_loss, "loss curves diverged across executors");
}

#[test]
fn eval_loss_is_pure() {
    let manifest = Manifest::load(artifacts_root()).unwrap();
    let mut tr =
        Trainer::new(&manifest, "tiny", FrozenPolicy::paper(), 1e-3).unwrap();
    let model = manifest.model("tiny").unwrap().clone();
    let ds = SyntheticDataset::new(&model, 77);
    let s = ds.sample(0);
    let a = tr.eval_loss(&s).unwrap();
    let b = tr.eval_loss(&s).unwrap();
    assert_eq!(a, b, "eval must not mutate state");
}

#[test]
fn manifest_artifacts_are_complete_for_all_models() {
    // Every component has fwd+bwd+bwdin; param owners have upd; shapes of
    // chained components line up along every edge.
    let manifest = Manifest::load(artifacts_root()).unwrap();
    for model in &manifest.models {
        for c in &model.components {
            for role in [Role::Fwd, Role::Bwd, Role::BwdIn] {
                assert!(
                    c.artifacts.contains_key(&role),
                    "{}/{} missing {role:?}",
                    model.name,
                    c.name
                );
            }
            if c.shares_params_with.is_none() {
                assert!(
                    c.artifacts.contains_key(&Role::Upd),
                    "{}/{} missing upd",
                    model.name,
                    c.name
                );
                assert!(c.params.is_some());
            }
        }
        // edge shape compatibility: producer's fwd out[0] feeds one of the
        // consumer's fwd inputs
        for (from, to) in &model.edges {
            let f = model.component(from).unwrap();
            let t = model.component(to).unwrap();
            let out = &f.artifact(Role::Fwd).unwrap().outs[0];
            let tins = &t.artifact(Role::Fwd).unwrap().ins;
            assert!(
                tins.iter().any(|i| i.dims == out.dims && i.dtype == out.dtype)
                    || out.dims.is_empty(),
                "{}: edge {from} -> {to}: no input of shape {:?}",
                model.name,
                out.dims
            );
        }
    }
}
