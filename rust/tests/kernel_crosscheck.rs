//! Cross-layer numerics: the L1 Pallas BAM-attention artifact (compiled
//! from python, executed via PJRT) against a from-scratch rust reference
//! that uses ONLY `bam::can_attend` — proving that all three layers agree
//! on the mask semantics and the attention math.
//!
//! Needs `make artifacts` first — gated behind the `artifacts` feature so
//! a clean checkout passes `cargo test` (run with
//! `cargo test --features artifacts` once artifacts are built).
#![cfg(feature = "artifacts")]

use cornstarch::bam::Bam;
use cornstarch::runtime::{AttnRuntime, Manifest};
use cornstarch::util::rng::Rng;

fn artifacts_root() -> std::path::PathBuf {
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}

/// Naive rust BAM attention: softmax over allowed keys, per head.
fn attention_rust(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bam: &Bam,
    h: usize,
    d: usize,
) -> Vec<f32> {
    let t = bam.len();
    let scale = 1.0 / (d as f32).sqrt();
    let idx = |tok: usize, head: usize, dim: usize| (tok * h + head) * d + dim;
    let mut out = vec![0.0f32; t * h * d];
    for i in 0..t {
        for head in 0..h {
            // scores over allowed j, streaming softmax for stability
            let mut scores = Vec::with_capacity(t);
            let mut max = f32::NEG_INFINITY;
            for j in 0..t {
                if bam.can_attend(i, j) {
                    let mut s = 0.0f32;
                    for dim in 0..d {
                        s += q[idx(i, head, dim)] * k[idx(j, head, dim)];
                    }
                    let s = s * scale;
                    max = max.max(s);
                    scores.push((j, s));
                }
            }
            let mut denom = 0.0f32;
            for (_, s) in scores.iter_mut() {
                *s = (*s - max).exp();
                denom += *s;
            }
            for (j, w) in &scores {
                let w = w / denom;
                for dim in 0..d {
                    out[idx(i, head, dim)] += w * v[idx(*j, head, dim)];
                }
            }
        }
    }
    out
}

#[test]
fn pallas_artifact_matches_rust_reference() {
    let manifest = Manifest::load(artifacts_root()).unwrap();
    let rt = AttnRuntime::load(&manifest, "attn128").unwrap();
    let t = rt.spec.tokens;
    let h = rt.spec.heads;
    let d = rt.spec.head_dim;

    // EE-style mask covering all three token-rule combinations.
    let mask = cornstarch::bam::generators::ee(
        &[t / 4, t / 4, t / 2 - (t / 4 + t / 8)],
        &[t / 4, t / 8],
    );
    assert_eq!(mask.len(), t, "mask length must equal artifact T");

    let n = t * h * d;
    let mut rng = Rng::new(99);
    let mk = |rng: &mut Rng| -> Vec<f32> {
        (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect()
    };
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));

    let (kernel_out, _ms) = rt
        .run(&q, &k, &v, &mask.bits_i32(), &mask.pos_i32())
        .unwrap();
    let rust_out = attention_rust(&q, &k, &v, &mask, h, d);

    assert_eq!(kernel_out.len(), rust_out.len());
    let mut max_err = 0.0f32;
    for (a, b) in kernel_out.iter().zip(&rust_out) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < 2e-4,
        "Pallas artifact vs rust reference: max abs err {max_err}"
    );
}

#[test]
fn fully_isolated_modalities_ignore_each_other() {
    // MP-style mask: two packed samples; value perturbations in sample 2
    // must not change sample 1's outputs at all.
    let manifest = Manifest::load(artifacts_root()).unwrap();
    let rt = AttnRuntime::load(&manifest, "attn128").unwrap();
    let t = rt.spec.tokens;
    let h = rt.spec.heads;
    let d = rt.spec.head_dim;
    let half = t / 2;
    let mask = cornstarch::bam::generators::mp(&[
        (half - 16, vec![16]),
        (half - 16, vec![16]),
    ]);
    assert_eq!(mask.len(), t);

    let n = t * h * d;
    let mut rng = Rng::new(5);
    let mk = |rng: &mut Rng| -> Vec<f32> {
        (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect()
    };
    let (q, k, mut v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let (out1, _) = rt
        .run(&q, &k, &v, &mask.bits_i32(), &mask.pos_i32())
        .unwrap();
    // Perturb every value of the second sample's tokens.
    for tok in half..t {
        for x in &mut v[tok * h * d..(tok + 1) * h * d] {
            *x += 7.5;
        }
    }
    let (out2, _) = rt
        .run(&q, &k, &v, &mask.bits_i32(), &mask.pos_i32())
        .unwrap();
    // Sample 1's outputs are bit-identical; sample 2's changed.
    assert_eq!(
        &out1[..half * h * d],
        &out2[..half * h * d],
        "cross-sample leakage through the mask"
    );
    assert_ne!(&out1[half * h * d..], &out2[half * h * d..]);
}
