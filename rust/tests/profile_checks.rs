//! The profile layer's contract:
//!
//! * the decomposition invariant — per device, compute + comm + idle
//!   equals the makespan to 1e-9, over random candidates on both pool
//!   kinds (homogeneous A40, mixed A40+A100);
//! * `explain --json` is byte-stable across runs of the real binary;
//! * sim-to-real drift is pinned by a golden tolerance: a profile a few
//!   percent off the flops model stays within `DRIFT_TOLERANCE`, and a
//!   recosted plan has ~zero residual drift;
//! * the checked-in sample `CalibrationProfile` parses under its schema
//!   (CI also validates it with an independent Python check).

use cornstarch::api::{ClusterSpec, PlanRequest, PlanningService};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::modality::Strategy;
use cornstarch::profile::{
    analyze, drift, recost, CalibrationProfile, StageSample, DRIFT_TOLERANCE,
};
use cornstarch::tuner::{build_plan, Candidate, FrozenSetting};
use cornstarch::util::check::{check, Gen};
use cornstarch::util::json::Json;

fn random_spec(g: &mut Gen) -> MllmSpec {
    match g.usize(0, 3) {
        0 => MllmSpec::vlm(Size::M, Size::M),
        1 => MllmSpec::alm(Size::M, Size::S),
        _ => MllmSpec::valm(Size::S, Size::M, Size::M),
    }
}

fn random_candidate(g: &mut Gen, spec: &MllmSpec, n_groups: usize) -> Candidate {
    let n_enc = spec.vision.is_some() as usize + spec.audio.is_some() as usize;
    let strategy = match g.usize(0, 3) {
        0 => Strategy::Cornstarch,
        1 => Strategy::Colocated,
        _ => Strategy::Replicated,
    };
    let enc_pps: Vec<usize> = match strategy {
        Strategy::Replicated => Vec::new(),
        Strategy::Colocated => vec![g.usize(1, 4); n_enc],
        Strategy::Cornstarch => (0..n_enc).map(|_| g.usize(1, 4)).collect(),
    };
    let chain_groups = if n_groups <= 1 {
        Vec::new()
    } else {
        match strategy {
            Strategy::Replicated => vec![g.usize(0, n_groups)],
            Strategy::Colocated => {
                let ge = g.usize(0, n_groups);
                let mut v = vec![ge; n_enc];
                v.push(g.usize(0, n_groups));
                v
            }
            Strategy::Cornstarch => {
                (0..=n_enc).map(|_| g.usize(0, n_groups)).collect()
            }
        }
    };
    Candidate {
        strategy,
        enc_pps,
        llm_pp: g.usize(1, 5),
        tp: 1 << g.usize(0, 2),
        cp: 1 << g.usize(0, 2),
        num_microbatches: g.usize(1, 17),
        frozen: FrozenSetting::ALL[g.usize(0, 3)],
        chain_groups,
    }
}

/// The tentpole invariant: the decomposition is exact. Every simulated
/// millisecond of every device lands in exactly one of compute / comm /
/// idle, on homogeneous and heterogeneous pools alike.
#[test]
fn decomposition_sums_to_makespan_on_random_candidates() {
    let clusters = [ClusterSpec::a40_default(), ClusterSpec::a40_a100_demo()];
    check("profile: compute+comm+idle == makespan", 60, |g| {
        let spec = random_spec(g);
        let cluster = &clusters[g.usize(0, clusters.len())];
        let cand = random_candidate(g, &spec, cluster.groups.len());
        let plan = build_plan(&spec, &cand, cluster);
        let m = plan.simulate();
        let a = analyze(&plan, &m.sim, cluster, spec.llm_tokens(), cand.cp);
        assert_eq!(a.makespan_ms, m.iteration_ms);
        for d in &a.devices {
            let sum = d.compute_ms + d.comm_ms + d.idle_ms;
            assert!(
                (sum - a.makespan_ms).abs() < 1e-9,
                "device {}: {sum} vs makespan {} under {cand:?}",
                d.device,
                a.makespan_ms
            );
            assert!(d.compute_ms >= 0.0 && d.comm_ms >= 0.0 && d.idle_ms >= 0.0);
        }
        // phases tile the same device-time: spans cover makespan per
        // device, and phase-attributed idle/comm re-sum to the totals
        let span: f64 = a.phases.iter().map(|p| p.span_ms).sum();
        assert!(
            (span - a.makespan_ms * a.devices.len() as f64).abs() < 1e-6,
            "phase spans {span} vs {} x {}",
            a.makespan_ms,
            a.devices.len()
        );
        assert!((a.phases.iter().map(|p| p.idle_ms).sum::<f64>()
            - a.total_idle_ms())
        .abs()
            < 1e-6);
        // every simulated device is owned by exactly one cluster group
        let grouped: usize = a.groups.iter().map(|gr| gr.devices).sum();
        assert_eq!(grouped, a.devices.len());
    });
}

/// The report's analysis agrees with the timeline it ships next to: the
/// same makespan, and a bubble fraction identical to the simulator's
/// `bubble_ratio` (all-device denominator — the satellite fix).
#[test]
fn report_analysis_is_consistent_with_timeline() {
    let req = PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S))
        .devices(8)
        .budget(8)
        .threads(2);
    let report = PlanningService::new().plan(&req).unwrap();
    let a = &report.analysis;
    assert_eq!(a.makespan_ms, report.timeline.iteration_ms);
    let n = a.devices.len() as f64;
    let bubble = (a.total_comm_ms() + a.total_idle_ms()) / (a.makespan_ms * n);
    assert!(
        (bubble - report.timeline.bubble_ratio).abs() < 1e-6,
        "decomposed bubble {bubble} vs simulated {}",
        report.timeline.bubble_ratio
    );
}

fn run_explain_json() -> Vec<u8> {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_cornstarch"))
        .args([
            "explain", "VLM-S", "--devices", "8", "--budget", "4",
            "--threads", "2", "--json", "--quiet",
        ])
        .output()
        .expect("spawn cornstarch");
    assert!(
        out.status.success(),
        "explain failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

#[test]
fn explain_json_double_runs_byte_identically() {
    let first = run_explain_json();
    let second = run_explain_json();
    assert!(!first.is_empty());
    assert_eq!(first, second, "explain --json must be byte-stable");
    let text = String::from_utf8(first).unwrap();
    let j = Json::parse(text.trim()).expect("explain emits valid JSON");
    let devices = j.get("devices").and_then(Json::as_arr).unwrap();
    assert!(!devices.is_empty());
    for d in devices {
        for k in ["compute_ms", "comm_ms", "idle_ms"] {
            assert!(d.get(k).and_then(Json::as_f64).is_some(), "missing {k}");
        }
    }
    let phases = j.get("phases").and_then(Json::as_arr).unwrap();
    assert_eq!(phases.len(), 3);
}

/// Golden sim-to-real tolerance: a measured profile that disagrees with
/// the flops model by a fixed few percent must stay within
/// `DRIFT_TOLERANCE`, and re-pricing the plan from the profile
/// ([`recost`]) must leave ~zero residual drift.
#[test]
fn drift_is_pinned_by_the_golden_tolerance() {
    assert_eq!(DRIFT_TOLERANCE, 0.05, "golden tolerance moved");
    let spec = MllmSpec::vlm(Size::M, Size::S);
    let cluster = ClusterSpec::a40_default();
    let cand = Candidate {
        strategy: Strategy::Cornstarch,
        enc_pps: vec![1],
        llm_pp: 3,
        tp: 1,
        cp: 1,
        num_microbatches: 8,
        frozen: FrozenSetting::Paper,
        chain_groups: Vec::new(),
    };
    let plan = build_plan(&spec, &cand, &cluster);
    // A synthetic "measured" profile: the model's own stage times
    // perturbed by a fixed +3% / -2% — the shape of real measurement
    // disagreement, with none of the hardware nondeterminism.
    let profile = CalibrationProfile {
        device_class: "A40".to_string(),
        samples: plan
            .stage_names
            .iter()
            .zip(&plan.graph.nodes)
            .enumerate()
            .map(|(i, (name, node))| {
                let f = if i % 2 == 0 { 1.03 } else { 0.98 };
                StageSample {
                    stage: name.clone(),
                    fwd_ms: node.cost.fwd_ms * f,
                    bwd_ms: node.cost.bwd_ms * f,
                    upd_ms: 1.0,
                }
            })
            .collect(),
    };
    let rep = drift(&plan, &profile);
    assert!(rep.unmatched.is_empty(), "unmatched: {:?}", rep.unmatched);
    assert_eq!(rep.stages.len(), plan.stage_names.len());
    assert!(rep.max_rel_err > 0.0);
    assert!(
        rep.within(DRIFT_TOLERANCE),
        "max drift {:.4} above tolerance {DRIFT_TOLERANCE}",
        rep.max_rel_err
    );
    // the measured makespan is a genuine re-simulation, not a copy
    assert!(rep.sim_makespan_ms > 0.0);
    assert!((rep.measured_makespan_ms - rep.sim_makespan_ms).abs() > 1e-9);
    // re-pricing the plan from the profile zeroes the drift
    let residual = drift(&recost(&plan, &profile), &profile);
    assert!(
        residual.max_rel_err < 1e-9,
        "residual drift {}",
        residual.max_rel_err
    );
    assert!((residual.sim_makespan_ms - rep.measured_makespan_ms).abs() < 1e-9);
    assert!(rep.render().contains("drift vs profile"));
    Json::parse(&rep.to_json().render()).expect("drift JSON parses");
}

/// A partial profile (LLM stages only) calibrates what it covers and
/// reports the rest as unmatched instead of failing.
#[test]
fn partial_profile_reports_unmatched_stages() {
    let spec = MllmSpec::vlm(Size::M, Size::S);
    let cluster = ClusterSpec::a40_default();
    let cand = Candidate {
        strategy: Strategy::Cornstarch,
        enc_pps: vec![1],
        llm_pp: 2,
        tp: 1,
        cp: 1,
        num_microbatches: 4,
        frozen: FrozenSetting::Paper,
        chain_groups: Vec::new(),
    };
    let plan = build_plan(&spec, &cand, &cluster);
    let profile = CalibrationProfile {
        device_class: "A40".to_string(),
        samples: plan
            .stage_names
            .iter()
            .zip(&plan.graph.nodes)
            .filter(|(name, _)| name.starts_with("llm"))
            .map(|(name, node)| StageSample {
                stage: name.clone(),
                fwd_ms: node.cost.fwd_ms,
                bwd_ms: node.cost.bwd_ms,
                upd_ms: 0.0,
            })
            .collect(),
    };
    let rep = drift(&plan, &profile);
    assert!(!rep.unmatched.is_empty());
    assert!(rep.unmatched.iter().all(|s| s.starts_with("enc:")));
    assert!(rep.stages.iter().all(|s| s.stage.starts_with("llm")));
    // matched stages are exact copies of the model here: zero drift
    assert!(rep.max_rel_err < 1e-12);
}

#[test]
fn checked_in_sample_profile_matches_schema() {
    let text = include_str!("../../examples/profiles/a40-sample.json");
    let p = CalibrationProfile::parse(text).expect("sample profile parses");
    assert_eq!(p.device_class, "A40");
    assert!(!p.samples.is_empty());
    assert!(p.samples.iter().any(|s| s.stage.starts_with("llm[")));
    // stage names are unique, so every sample feeds MeasuredTimes
    assert_eq!(p.measured_times().len(), p.samples.len());
    // and the file re-renders from its parsed form (no stray fields)
    let reparsed = CalibrationProfile::parse(&p.to_json().render()).unwrap();
    assert_eq!(p, reparsed);
}
