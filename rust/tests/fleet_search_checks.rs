//! Property and regression harness for the scalable fleet-carve search
//! and elastic re-planning (ISSUE 10):
//!
//! * **branch-and-bound is exact** — on randomized small pools (seeded
//!   RNG, homogeneous and heterogeneous) the B&B engine returns the same
//!   aggregate as exhaustive enumeration, and agrees with it on
//!   feasibility;
//! * **local search is safe** — it never returns an infeasible carve
//!   when one exists, never beats the exhaustive optimum, and stays
//!   within a pinned tolerance of it;
//! * **every returned carve is verifier-clean** — the V005-family
//!   partition lints pass for whatever engine answered;
//! * **elastic warm re-planning is surgical** — losing one GPU re-plans
//!   only the tenant that held it (every other tenant's `PlanDiff` is
//!   empty) and is byte-deterministic across runs;
//! * **past-cap pools plan instead of refusing** — a carve space beyond
//!   the exact cap degrades to a heuristic engine recorded in
//!   `FleetProvenance::search_mode` (the pre-heuristic behaviour was an
//!   `InvalidRequest`).

use cornstarch::api::fleet::{MAX_BNB_CARVES, MAX_PARTITIONS};
use cornstarch::api::{
    carve_count, CachePolicy, ClusterSpec, DeviceClass, DeviceGroup,
    FleetRequest, PlanError, PlanRequest, PlanningService, SearchMode,
};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::util::rng::Rng;
use cornstarch::verify::verify_partition;

/// Pinned lower bound on local search quality: the hill-climb must land
/// within this fraction of the exhaustive optimum on the small pools the
/// harness can enumerate.
const LOCAL_TOLERANCE: f64 = 0.75;

/// A random small pool: either one homogeneous A40 group or an
/// A40 + A100-80G mix, sized so exhaustive enumeration stays trivial.
fn random_pool(rng: &mut Rng, trial: usize) -> ClusterSpec {
    if rng.below(2) == 1 {
        ClusterSpec {
            name: format!("rand-hetero-{trial}"),
            groups: vec![
                DeviceGroup {
                    device: DeviceClass::a40(),
                    count: rng.range(2, 5),
                    link_gbps: 32.0,
                },
                DeviceGroup {
                    device: DeviceClass::a100_80g(),
                    count: rng.range(2, 5),
                    link_gbps: 300.0,
                },
            ],
        }
    } else {
        ClusterSpec::homogeneous(
            &format!("rand-homog-{trial}"),
            DeviceClass::a40(),
            rng.range(3, 7),
            32.0,
        )
    }
}

/// `n_tenants` small tenants (alternating VLM-S / ALM-S) with a cheap
/// search budget, floor disabled, shared in-process plan store.
fn fleet_of(cluster: ClusterSpec, n_tenants: usize) -> FleetRequest {
    let specs = [
        MllmSpec::vlm(Size::S, Size::S),
        MllmSpec::alm(Size::S, Size::S),
        MllmSpec::vlm(Size::S, Size::S),
    ];
    let mut freq = FleetRequest::new(cluster)
        .fairness_floor(0.0)
        .cache_memory();
    for (i, spec) in specs.into_iter().take(n_tenants).enumerate() {
        freq = freq.tenant(
            &format!("t{i}"),
            PlanRequest::default_for(spec).budget(6).threads(1),
        );
    }
    freq
}

#[test]
fn branch_and_bound_matches_the_exhaustive_optimum() {
    let mut rng = Rng::new(0xF1EE7_CA4E);
    let service = PlanningService::new();
    for trial in 0..6 {
        let cluster = random_pool(&mut rng, trial);
        let n_tenants = 2 + trial % 2;
        let exact = service.plan_fleet(
            &fleet_of(cluster.clone(), n_tenants)
                .search_mode(SearchMode::Exact),
        );
        let bnb = service.plan_fleet(
            &fleet_of(cluster.clone(), n_tenants)
                .search_mode(SearchMode::BranchAndBound),
        );
        match (exact, bnb) {
            (Ok(e), Ok(b)) => {
                let (ea, ba) =
                    (e.aggregate_throughput, b.aggregate_throughput);
                assert!(
                    (ea - ba).abs() <= 1e-9 * ea.max(1.0),
                    "trial {trial} on {}: exact {ea} vs bnb {ba} \
                     (exact carve {}, bnb carve {})",
                    cluster.name,
                    e.partition.label(),
                    b.partition.label(),
                );
                assert_eq!(
                    b.provenance.search_mode,
                    SearchMode::BranchAndBound
                );
                assert!(b.partition.respects(&cluster));
                assert!(
                    verify_partition(&b.partition, &cluster).is_clean(),
                    "trial {trial}: {}",
                    b.partition.label()
                );
            }
            (
                Err(PlanError::InfeasibleFleet(_)),
                Err(PlanError::InfeasibleFleet(_)),
            ) => {}
            (e, b) => panic!(
                "trial {trial} on {}: engines disagree on feasibility: \
                 exact={e:?} bnb={b:?}",
                cluster.name
            ),
        }
    }
}

#[test]
fn local_search_is_feasible_and_within_tolerance_of_exact() {
    let mut rng = Rng::new(0x10CA1_5EA4);
    let service = PlanningService::new();
    for trial in 0..6 {
        let cluster = random_pool(&mut rng, trial);
        let n_tenants = 2 + trial % 2;
        let exact = service.plan_fleet(
            &fleet_of(cluster.clone(), n_tenants)
                .search_mode(SearchMode::Exact),
        );
        let local = service.plan_fleet(
            &fleet_of(cluster.clone(), n_tenants)
                .search_mode(SearchMode::LocalSearch),
        );
        let Ok(e) = exact else {
            // Nothing feasible at all — the hill-climb must agree.
            assert!(
                local.is_err(),
                "trial {trial}: local found a carve exact says cannot \
                 exist"
            );
            continue;
        };
        let l = local.unwrap_or_else(|err| {
            panic!(
                "trial {trial} on {}: exact is feasible but local \
                 search failed: {err}",
                cluster.name
            )
        });
        assert_eq!(l.provenance.search_mode, SearchMode::LocalSearch);
        assert!(
            l.aggregate_throughput
                >= LOCAL_TOLERANCE * e.aggregate_throughput - 1e-9,
            "trial {trial} on {}: local {} fell below {LOCAL_TOLERANCE} \
             of exact {} (carve {})",
            cluster.name,
            l.aggregate_throughput,
            e.aggregate_throughput,
            l.partition.label(),
        );
        // An optimum is an upper bound for any heuristic answer.
        assert!(
            l.aggregate_throughput
                <= e.aggregate_throughput + 1e-6 * e.aggregate_throughput,
            "trial {trial}: local {} beat the exhaustive optimum {}",
            l.aggregate_throughput,
            e.aggregate_throughput,
        );
        assert!(verify_partition(&l.partition, &cluster).is_clean());
    }
}

#[test]
fn one_gpu_loss_relocates_at_most_the_affected_tenant() {
    let service = PlanningService::new();
    let base_req = fleet_of(ClusterSpec::a40_a100_demo(), 2);
    let base = service
        .plan_fleet(&base_req)
        .expect("two S tenants fit the demo pool");

    // The repair takes the lost device from the tenant holding the most
    // of the lost group — that tenant is the only one allowed to change.
    let affected = (0..2)
        .max_by_key(|&t| base.partition.slices[t][0])
        .unwrap();
    let replan = service
        .plan_fleet(
            &base_req
                .clone()
                .warm_start(&base.partition)
                .device_lost(0, 1),
        )
        .expect("the shrunk pool still hosts both tenants");

    assert!(replan.provenance.warm_start);
    assert_eq!(replan.provenance.search_mode, SearchMode::LocalSearch);
    // Surgical carve repair: one group-0 device off the affected
    // tenant's slice, everyone else's slice untouched.
    for (t, slice) in replan.partition.slices.iter().enumerate() {
        let mut want = base.partition.slices[t].clone();
        if t == affected {
            want[0] -= 1;
        }
        assert_eq!(
            *slice,
            want,
            "tenant {t}: {} -> {}",
            base.partition.label(),
            replan.partition.label()
        );
    }
    // The acceptance criterion: every unaffected tenant's PlanDiff
    // against the pre-loss answer is empty.
    let affected_name = base.tenants[affected].name.clone();
    for (name, diff) in replan.diff_from(&base) {
        if name != affected_name {
            assert!(
                diff.is_empty(),
                "unaffected tenant {name} was re-planned:\n{}",
                diff.render()
            );
        }
    }
}

#[test]
fn elastic_replan_is_byte_deterministic() {
    let service = PlanningService::new();
    // Fresh per-call caches: both runs search from scratch, so even the
    // provenance counters must come out identical.
    let base_req = fleet_of(ClusterSpec::a40_a100_demo(), 2)
        .cache_policy(CachePolicy::Fresh);
    let base = service.plan_fleet(&base_req).expect("base fleet plans");
    let elastic = base_req
        .clone()
        .warm_start(&base.partition)
        .device_lost(1, 1);
    let first = service.plan_fleet(&elastic).expect("first re-plan");
    let second = service.plan_fleet(&elastic).expect("second re-plan");
    assert_eq!(first.partition, second.partition);
    assert_eq!(
        first.render(),
        second.render(),
        "elastic re-planning must be byte-deterministic"
    );
}

#[test]
fn past_the_exact_cap_plans_heuristically_instead_of_refusing() {
    // 3 groups x 8 devices, 3 tenants: C(10,2)^3 = 91,125 carves — past
    // the exact cap, within the branch-and-bound window.
    let cluster = ClusterSpec {
        name: "pool-3x8".to_string(),
        groups: vec![
            DeviceGroup {
                device: DeviceClass::a40(),
                count: 8,
                link_gbps: 32.0,
            },
            DeviceGroup {
                device: DeviceClass::a100_80g(),
                count: 8,
                link_gbps: 300.0,
            },
            DeviceGroup {
                device: DeviceClass::a40(),
                count: 8,
                link_gbps: 32.0,
            },
        ],
    };
    let carves = carve_count(&cluster, 3);
    assert_eq!(carves, 45u128.pow(3), "C(10,2)^3 carve space");
    assert!(carves > MAX_PARTITIONS as u128 && carves <= MAX_BNB_CARVES);

    let freq = fleet_of(cluster.clone(), 3).search_evals(32);
    let report = PlanningService::new().plan_fleet(&freq).expect(
        "a past-cap pool must degrade to a heuristic engine, not refuse",
    );
    assert_eq!(
        report.provenance.search_mode,
        SearchMode::BranchAndBound,
        "auto mode picks branch-and-bound inside the B&B window"
    );
    assert!(!report.provenance.warm_start);
    assert!(report.provenance.partitions_considered > 0);
    assert!(report.partition.respects(&cluster));
    assert!(verify_partition(&report.partition, &cluster).is_clean());
    assert!(
        report.render().contains("branch_and_bound search"),
        "provenance line names the engine:\n{}",
        report.render()
    );
}
