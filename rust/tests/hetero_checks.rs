//! Heterogeneous device pools, end-to-end, plus the lower-bound property
//! harness the search's exactness claim rests on.
//!
//! * acceptance: planning the paper's VLM-L on the mixed
//!   `a40x4-a100x4.json` pool places every LLM stage on the A100 group
//!   and at least one frozen encoder stage on the A40 group, beats the
//!   best all-A40 plan of the same size on simulated makespan, and its
//!   cache v4 entry carries a fingerprint distinct from (and never
//!   satisfied by) the homogeneous `a40x8` signature;
//! * golden: an old single-device cluster JSON still reproduces the
//!   PR 3 plan byte-for-byte — the hetero generalization must not
//!   perturb homogeneous answers at all;
//! * property: for randomly sampled candidates (seeded via `util::rng`),
//!   the simulated 1F1B makespan is ≥ BOTH tuner lower bounds
//!   (device-busy and critical-path), on homogeneous and mixed pools
//!   alike — the invariant that makes lower-bound pruning safe.

use cornstarch::api::{
    ClusterSpec, PlanRequest, PlanningService,
};
use cornstarch::cost::Device;
use cornstarch::modality::{
    planner, MultimodalModule, MultimodalParallelSpec, Strategy,
};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::tuner::{
    bounds_ms, build_plan, Candidate, FrozenSetting, PlanCache,
};
use cornstarch::util::check::{check, Gen};

fn demo_cluster_path() -> &'static str {
    concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/clusters/a40x4-a100x4.json"
    )
}

/// The JSON example and the in-code demo constructor must stay in sync —
/// the reproduce harness uses the constructor, the CLI docs the file.
#[test]
fn demo_cluster_file_matches_the_constructor() {
    let from_file =
        ClusterSpec::load(std::path::Path::new(demo_cluster_path()))
            .unwrap();
    assert_eq!(from_file, ClusterSpec::a40_a100_demo());
    assert!(from_file.is_heterogeneous());
    assert_eq!(from_file.devices(), 8);
    assert_eq!(from_file.groups[0].device.name, "A40");
    assert_eq!(from_file.groups[1].device.name, "A100-80G");
}

/// The ISSUE's acceptance scenario, end to end through the facade.
#[test]
fn vlm_l_on_the_mixed_pool_splits_frozen_encoders_from_the_llm() {
    let mut cache_path = std::env::temp_dir();
    cache_path.push(format!(
        "cornstarch-hetero-accept-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&cache_path);
    let cache = cache_path.to_string_lossy().into_owned();

    let spec = MllmSpec::vlm(Size::M, Size::L); // the paper's VLM-L
    let hetero_cluster =
        ClusterSpec::load(std::path::Path::new(demo_cluster_path()))
            .unwrap();
    let service = PlanningService::new();
    let hetero = service
        .plan(
            &PlanRequest::default_for(spec.clone())
                .cluster(hetero_cluster.clone())
                .threads(2)
                .cache_file(&cache),
        )
        .unwrap();

    // Placement: every LLM stage claims the 80 GB A100 group; at least
    // one frozen encoder stage rides the cheap A40 group.
    let plan = &hetero.plan;
    assert_eq!(plan.stage_groups.len(), plan.stage_names.len());
    let mut saw_llm = false;
    let mut enc_on_a40 = false;
    for (name, &g) in plan.stage_names.iter().zip(&plan.stage_groups) {
        if name.starts_with("llm") {
            saw_llm = true;
            assert_eq!(
                g, 1,
                "LLM stage {name} landed off the A100 group"
            );
        }
        // "enc:" (modality-parallel) or "enc[" (colocated fusion)
        if name.starts_with("enc") && g == 0 {
            enc_on_a40 = true;
        }
    }
    assert!(saw_llm);
    assert!(
        enc_on_a40,
        "no frozen encoder stage landed on the A40 group: {:?} / {:?}",
        plan.stage_names, plan.stage_groups
    );
    // The report's verdicts say the same thing in hardware names, and
    // every stage fits the budget of the device it actually landed on.
    assert!(hetero.fits_budget());
    assert!(hetero
        .stage_verdicts
        .iter()
        .any(|v| v.stage.starts_with("enc") && v.device == "A40"));
    assert!(hetero
        .stage_verdicts
        .iter()
        .filter(|v| v.stage.starts_with("llm"))
        .all(|v| v.device == "A100-80G"
            && v.budget_bytes == 80_000_000_000));

    // The mixed pool beats the best all-A40 plan of the same size.
    let a40x8 = ClusterSpec::a40_default().with_devices(8);
    let all_a40 = service
        .plan(
            &PlanRequest::default_for(spec.clone())
                .cluster(a40x8.clone())
                .threads(2),
        )
        .unwrap();
    assert!(
        hetero.timeline.iteration_ms < all_a40.timeline.iteration_ms,
        "mixed pool {:.1} ms did not beat all-A40 {:.1} ms",
        hetero.timeline.iteration_ms,
        all_a40.timeline.iteration_ms
    );

    // Cache v4: the persisted entry's fingerprint covers the full pool,
    // never aliases the homogeneous a40x8 signature, and a lookup under
    // the homogeneous fingerprint is never satisfied by it.
    assert_ne!(hetero.provenance.cluster, a40x8.fingerprint());
    assert_ne!(hetero.provenance.signature, all_a40.provenance.signature);
    let store = PlanCache::load(&cache_path);
    assert!(!store.is_empty());
    let entry = store
        .lookup(&hetero.provenance.signature, &hetero.provenance.cluster)
        .expect("the hetero answer was persisted");
    assert_eq!(entry.cluster, hetero_cluster.fingerprint());
    assert!(store
        .lookup(&hetero.provenance.signature, &a40x8.fingerprint())
        .is_none());
    // the winning plan's assignment round-tripped through the cache
    assert!(!entry.best().candidate.chain_groups.is_empty());
    assert_eq!(
        entry.best().candidate,
        hetero.winner().candidate
    );

    // And a warm re-query instantiates the identical heterogeneous plan.
    let warm = service
        .plan(
            &PlanRequest::default_for(spec.clone())
                .cluster(hetero_cluster)
                .threads(2)
                .cache_file(&cache),
        )
        .unwrap();
    assert!(warm.provenance.cache_hit);
    assert_eq!(warm.winner(), hetero.winner());
    assert_eq!(warm.plan.stage_groups, hetero.plan.stage_groups);
    assert!(
        (warm.timeline.iteration_ms - hetero.timeline.iteration_ms).abs()
            < 1e-9
    );
    let _ = std::fs::remove_file(&cache_path);
}

/// Golden: a pre-hetero single-device cluster JSON answers with
/// byte-for-byte the PR 3 plan (paper spec constants, A40 device model).
#[test]
fn old_single_device_cluster_json_reproduces_the_golden_plan() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/clusters/a40x8.json"
    );
    let cluster = ClusterSpec::load(std::path::Path::new(path)).unwrap();
    assert!(!cluster.is_heterogeneous());

    let spec = MllmSpec::vlm(Size::M, Size::S);
    let report = PlanningService::new()
        .plan(
            &PlanRequest::default_for(spec.clone())
                .cluster(cluster)
                .threads(2),
        )
        .unwrap();
    // homogeneous candidates stay assignment-free (cache keys, labels,
    // and equality are unchanged from PR 3)
    assert!(report.winner().candidate.chain_groups.is_empty());
    assert!(report
        .plan
        .stage_groups
        .iter()
        .all(|&g| g == 0));

    // the pre-redesign construction: paper-default spec + Device::a40()
    let cand = &report.winner().candidate;
    let mut mm = MultimodalModule::from_spec(&spec);
    cand.frozen.apply(&mut mm);
    let mut ps = MultimodalParallelSpec::paper_default(
        &cand.enc_pps,
        cand.llm_pp,
        cand.tp,
        cand.cp,
    );
    ps.num_microbatches = cand.num_microbatches;
    let legacy = planner::plan(cand.strategy, &mm, &ps, Device::a40());

    assert_eq!(report.plan.stage_names, legacy.stage_names);
    assert_eq!(report.plan.stage_mem, legacy.stage_mem);
    assert_eq!(report.plan.n_gpus, legacy.n_gpus);
    assert!(report.plan.graph.comm_ms == legacy.graph.comm_ms);
    for (a, b) in report.plan.graph.nodes.iter().zip(&legacy.graph.nodes)
    {
        assert_eq!(a.device, b.device);
        assert_eq!(a.preds, b.preds);
        // bit-exact, not approximate: the hetero generalization must
        // not perturb the homogeneous time model at all
        assert!(a.cost.fwd_ms == b.cost.fwd_ms);
        assert!(a.cost.bwd_ms == b.cost.bwd_ms);
    }
    let m = legacy.simulate();
    assert!(
        (m.iteration_ms - report.timeline.iteration_ms).abs() < 1e-9
    );
}

fn random_spec(g: &mut Gen) -> MllmSpec {
    match g.usize(0, 3) {
        0 => MllmSpec::vlm(Size::M, Size::M),
        1 => MllmSpec::alm(Size::M, Size::S),
        _ => MllmSpec::valm(Size::S, Size::M, Size::M),
    }
}

fn random_candidate(g: &mut Gen, spec: &MllmSpec, n_groups: usize) -> Candidate {
    let n_enc = spec.vision.is_some() as usize + spec.audio.is_some() as usize;
    let strategy = match g.usize(0, 3) {
        0 => Strategy::Cornstarch,
        1 => Strategy::Colocated,
        _ => Strategy::Replicated,
    };
    let enc_pps: Vec<usize> = match strategy {
        Strategy::Replicated => Vec::new(),
        // colocated demands equal encoder stage counts
        Strategy::Colocated => vec![g.usize(1, 4); n_enc],
        Strategy::Cornstarch => (0..n_enc).map(|_| g.usize(1, 4)).collect(),
    };
    let chain_groups = if n_groups <= 1 {
        Vec::new()
    } else {
        match strategy {
            Strategy::Replicated => vec![g.usize(0, n_groups)],
            // colocated fuses encoders onto one shared group
            Strategy::Colocated => {
                let ge = g.usize(0, n_groups);
                let mut v = vec![ge; n_enc];
                v.push(g.usize(0, n_groups));
                v
            }
            Strategy::Cornstarch => {
                (0..=n_enc).map(|_| g.usize(0, n_groups)).collect()
            }
        }
    };
    Candidate {
        strategy,
        enc_pps,
        llm_pp: g.usize(1, 5),
        tp: 1 << g.usize(0, 2),
        cp: 1 << g.usize(0, 2),
        num_microbatches: g.usize(1, 17),
        frozen: FrozenSetting::ALL[g.usize(0, 3)],
        chain_groups,
    }
}

/// The search's exactness claim rests on this invariant and it was
/// previously untested: for ANY candidate, the simulated 1F1B makespan
/// is at least the device-busy bound AND at least the critical-path
/// bound. If either ever exceeded the simulation, bound-ascending
/// pruning could discard the true optimum.
#[test]
fn simulated_makespan_dominates_both_lower_bounds() {
    let clusters = [
        ClusterSpec::a40_default(),
        ClusterSpec::a40_a100_demo(),
    ];
    check("sim >= device-busy and critical-path bounds", 60, |g| {
        let spec = random_spec(g);
        let cluster = &clusters[g.usize(0, clusters.len())];
        let cand = random_candidate(g, &spec, cluster.groups.len());
        let plan = build_plan(&spec, &cand, cluster);
        let (busy, critical) = bounds_ms(&plan);
        let sim = plan.simulate().iteration_ms;
        assert!(
            busy <= sim + 1e-6,
            "device-busy bound {busy:.3} > sim {sim:.3} for {}",
            cand.label()
        );
        assert!(
            critical <= sim + 1e-6,
            "critical-path bound {critical:.3} > sim {sim:.3} for {}",
            cand.label()
        );
        assert!(busy > 0.0 && critical > 0.0);
    });
}
