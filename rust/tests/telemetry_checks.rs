//! Telemetry acceptance: the observability layer is off-path and
//! deterministic.
//!
//! * golden counters: the registry delta one `plan()` call fires is
//!   identical across a double run, and every search-side provenance
//!   number cross-checks against it exactly;
//! * off-path: the tuned winner (and the whole rendered report) is
//!   byte-identical with tracing enabled vs disabled;
//! * trace validity: a `cornstarch tune --trace t.json` run emits a
//!   Chrome trace-event JSON array (`name`/`ph`/`ts`/`pid`/`tid`,
//!   `dur` on `X` slices) whose spans nest, loadable in Perfetto.

use cornstarch::api::{PlanRequest, PlanningService};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::telemetry::{self, key as tkey, Snapshot};
use cornstarch::util::json::Json;

/// A small fixed request every test plans: VLM-S on 8 × A40, two
/// worker threads, no cache file (so each call searches).
fn fixed_request() -> PlanRequest {
    PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S))
        .devices(8)
        .threads(2)
}

fn plan_with_delta(
    req: &PlanRequest,
) -> (Snapshot, cornstarch::api::PlanReport) {
    let before = telemetry::snapshot();
    let report = PlanningService::new().plan(req).expect("plans");
    (telemetry::snapshot().delta_since(&before), report)
}

/// Golden: the counter delta of a fixed `plan()` call is deterministic
/// (double run, byte-identical render) and agrees with the provenance
/// numbers the search itself reported.
#[test]
fn counter_snapshot_is_deterministic_and_matches_provenance() {
    let req = fixed_request();
    let (d1, r1) = plan_with_delta(&req);
    let (d2, r2) = plan_with_delta(&req);
    assert_eq!(d1, d2, "counter deltas must not drift between runs");
    assert_eq!(d1.render(), d2.render());
    assert_eq!(
        r1.winner().candidate.label(),
        r2.winner().candidate.label()
    );

    // cross-check: registry counters == the search's own accounting
    let p = &r1.provenance;
    assert!(!p.cache_hit);
    assert_eq!(d1.get(tkey::EVALUATED), p.evaluated as u64);
    assert_eq!(d1.get(tkey::PRUNED_LOWER_BOUND), p.pruned as u64);
    // on the homogeneous A40 pool every raw candidate either survives
    // enumeration or is cut by the memory model — no group-capacity
    // dimension exists to expand or prune placements
    assert_eq!(d1.get(tkey::PRUNED_GROUP_CAPACITY), 0);
    assert_eq!(
        d1.get(tkey::CANDIDATES_ENUMERATED)
            - d1.get(tkey::PRUNED_MEMORY),
        p.total_candidates as u64
    );
    assert_eq!(p.evaluated + p.pruned, p.total_candidates);
    assert_eq!(d1.get(tkey::CACHE_MISS), 1);
    assert_eq!(d1.get(tkey::CACHE_HIT), 0);
    assert_eq!(d1.get(tkey::CACHE_WRITE), 0, "no cache file, no write");

    // and the provenance's embedded stats block is that same delta
    let stats = p.stats;
    assert_eq!(
        stats.candidates_enumerated,
        d1.get(tkey::CANDIDATES_ENUMERATED)
    );
    assert_eq!(stats.evaluated, d1.get(tkey::EVALUATED));
    assert_eq!(stats.pruned_memory, d1.get(tkey::PRUNED_MEMORY));
    assert_eq!(
        stats.pruned_total(),
        d1.get(tkey::PRUNED_LOWER_BOUND) + d1.get(tkey::PRUNED_MEMORY)
    );
    assert_eq!(stats.cache_misses, 1);
    // the render embeds the same numbers the JSON form carries
    let j = stats.to_json();
    assert_eq!(
        j.get("evaluated").and_then(Json::as_i64),
        Some(stats.evaluated as i64)
    );
    assert!(r2.provenance.stats == stats, "stats drifted across runs");
}

/// Off-path: enabling tracing changes nothing about the answer — the
/// winner, the counters, and the whole rendered report stay
/// byte-identical.
#[test]
fn winner_is_byte_identical_with_telemetry_on_and_off() {
    let req = fixed_request();
    let (d_off, r_off) = plan_with_delta(&req);
    telemetry::enable_trace();
    let (d_on, r_on) = plan_with_delta(&req);
    telemetry::disable_trace();
    assert_eq!(
        r_off.render(),
        r_on.render(),
        "tracing must not perturb the report"
    );
    assert_eq!(d_off, d_on, "tracing must not perturb the counters");
    assert_eq!(
        r_off.winner().candidate.label(),
        r_on.winner().candidate.label()
    );
    assert!(r_off.timeline.iteration_ms == r_on.timeline.iteration_ms);
}

/// End-to-end trace validity: run the real binary with `--trace`, then
/// hold the output to the Chrome trace-event contract — a JSON array
/// of events with `name`/`ph`/`ts`/`pid`/`tid` (+ `dur` on `X`
/// slices), wall-clock spans properly nested per lane, and the
/// winner's simulated timeline present on the virtual-time pid.
#[test]
fn trace_flag_emits_nested_chrome_trace_events() {
    let mut path = std::env::temp_dir();
    path.push(format!(
        "cornstarch-telemetry-trace-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_cornstarch"))
        .args([
            "tune",
            "VLM-S",
            "--devices",
            "8",
            "--budget",
            "4",
            "--threads",
            "2",
            "--quiet",
            "--trace",
            path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn the cornstarch binary");
    assert!(
        out.status.success(),
        "tune --trace failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = std::fs::read_to_string(&path).expect("trace written");
    let _ = std::fs::remove_file(&path);
    let j = Json::parse(&text).expect("trace must be valid JSON");
    let events = j.as_arr().expect("trace must be a JSON array");
    assert!(!events.is_empty());
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).expect("ph");
        assert!(ph == "X" || ph == "i", "unexpected phase {ph:?}");
        assert!(e.get("name").and_then(Json::as_str).is_some());
        assert!(e.get("ts").and_then(Json::as_i64).is_some());
        assert!(e.get("pid").and_then(Json::as_i64).is_some());
        assert!(e.get("tid").and_then(Json::as_i64).is_some());
        if ph == "X" {
            assert!(e.get("dur").and_then(Json::as_i64).unwrap() >= 0);
        }
    }
    // the named planning spans are all present
    let names: Vec<&str> = events
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    for want in ["plan VLM-S", "tune VLM-S devices=8", "search"] {
        assert!(
            names.iter().any(|n| n.starts_with(want)),
            "missing span {want:?} in {names:?}"
        );
    }
    // spans nest: on each wall-clock lane, any two X slices either
    // nest or are disjoint (never partially overlap)
    let slices = |pid: i64, tid: i64| -> Vec<(i64, i64)> {
        events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("X")
                    && e.get("pid").and_then(Json::as_i64) == Some(pid)
                    && e.get("tid").and_then(Json::as_i64) == Some(tid)
            })
            .map(|e| {
                let ts = e.get("ts").and_then(Json::as_i64).unwrap();
                let dur = e.get("dur").and_then(Json::as_i64).unwrap();
                (ts, ts + dur)
            })
            .collect()
    };
    let lanes: std::collections::BTreeSet<(i64, i64)> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .map(|e| {
            (
                e.get("pid").and_then(Json::as_i64).unwrap(),
                e.get("tid").and_then(Json::as_i64).unwrap(),
            )
        })
        .collect();
    for (pid, tid) in &lanes {
        // only wall-clock lanes (pid 1) carry the nesting claim; the
        // sim's virtual-time lanes are one flat row per device
        if *pid != 1 {
            continue;
        }
        let ss = slices(*pid, *tid);
        for (i, a) in ss.iter().enumerate() {
            for b in ss.iter().skip(i + 1) {
                let disjoint = a.1 <= b.0 || b.1 <= a.0;
                let nested = (a.0 <= b.0 && b.1 <= a.1)
                    || (b.0 <= a.0 && a.1 <= b.1);
                assert!(
                    disjoint || nested,
                    "partially overlapping spans on lane {pid}/{tid}: \
                     {a:?} vs {b:?}"
                );
            }
        }
    }
    // the winner's simulated schedule landed on the virtual-time pid
    assert!(
        lanes.iter().any(|(pid, _)| *pid == 2),
        "no simulator timeline lanes in the trace"
    );
    assert!(names.iter().any(|n| n.starts_with("fwd ")));
    assert!(names.iter().any(|n| n.starts_with("bwd ")));
}
