//! Planning-facade acceptance.
//!
//! * golden: `PlanRequest::default_for(mllm)` reproduces byte-for-byte
//!   the plan `cornstarch plan --strategy tuned` chose before the
//!   redesign (paper-default spec constants, A40 device model);
//! * cluster: the CLI's `--cluster examples/clusters/a40x8.json` request
//!   and the programmatic `PlanningService::plan` answer identically,
//!   and a non-A40 spec (80 GB/device) readmits OOM-pruned candidates
//!   and changes the chosen plan;
//! * cache: schema v4 round-trips through disk property-style, v3 files
//!   degrade to an empty cache, and a v4 entry stripped of its cluster
//!   fingerprint is rejected rather than defaulted.

use cornstarch::api::{
    ClusterSpec, PlanError, PlanRequest, PlanningService,
};
use cornstarch::cost::Device;
use cornstarch::modality::{
    planner, MultimodalModule, MultimodalParallelSpec, Strategy,
};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::tuner::{
    build_plan, enumerate, tune, CacheEntry, Candidate, FrozenSetting,
    PlanCache, PlanSummary, SearchSpace, TuneRequest,
};
use cornstarch::util::check::{check, Gen};

/// The pre-redesign tuned path reproduced explicitly: the winning
/// candidate instantiated with `MultimodalParallelSpec::paper_default`
/// (0.5 ms comm constant) on `Device::a40()` — exactly what
/// `cornstarch plan --strategy tuned` built before `ClusterSpec`
/// existed.
fn legacy_plan_for(
    spec: &MllmSpec,
    cand: &Candidate,
) -> cornstarch::modality::Plan {
    let mut mm = MultimodalModule::from_spec(spec);
    cand.frozen.apply(&mut mm);
    let mut ps = MultimodalParallelSpec::paper_default(
        &cand.enc_pps,
        cand.llm_pp,
        cand.tp,
        cand.cp,
    );
    ps.num_microbatches = cand.num_microbatches;
    planner::plan(cand.strategy, &mm, &ps, Device::a40())
}

fn assert_plans_identical(
    a: &cornstarch::modality::Plan,
    b: &cornstarch::modality::Plan,
) {
    assert_eq!(a.stage_names, b.stage_names);
    assert_eq!(a.stage_mem, b.stage_mem);
    assert_eq!(a.n_gpus, b.n_gpus);
    assert_eq!(a.num_microbatches, b.num_microbatches);
    assert_eq!(a.microbatch_size, b.microbatch_size);
    assert!(a.graph.comm_ms == b.graph.comm_ms, "comm pricing drifted");
    assert_eq!(a.graph.nodes.len(), b.graph.nodes.len());
    for (x, y) in a.graph.nodes.iter().zip(&b.graph.nodes) {
        assert_eq!(x.device, y.device);
        assert_eq!(x.preds, y.preds);
        // bit-exact, not approximate: the facade must not perturb the
        // time model at all
        assert!(x.cost.fwd_ms == y.cost.fwd_ms);
        assert!(x.cost.bwd_ms == y.cost.bwd_ms);
    }
}

/// Golden: the facade's default request answers with byte-for-byte the
/// plan the pre-redesign `plan --strategy tuned` path chose.
#[test]
fn golden_default_request_reproduces_the_pre_redesign_tuned_plan() {
    let spec = MllmSpec::vlm(Size::M, Size::M);

    // the old door: TuneRequest::new + tune + instantiate
    let mut treq = TuneRequest::new(spec.clone(), 16);
    treq.threads = 2;
    let outcome = tune(&treq).unwrap();

    // the new door: the facade's default request
    let req = PlanRequest::default_for(spec.clone()).threads(2);
    let report = PlanningService::new().plan(&req).unwrap();

    assert_eq!(
        report.winner().candidate,
        outcome.entry.best().candidate,
        "facade chose a different candidate than the tuned path"
    );
    assert!(
        (report.winner().iteration_ms
            - outcome.entry.best().iteration_ms)
            .abs()
            < 1e-12
    );
    // and byte-for-byte against the pre-redesign plan construction
    let legacy = legacy_plan_for(&spec, &report.winner().candidate);
    assert_plans_identical(&report.plan, &legacy);
    let m = legacy.simulate();
    assert!((m.iteration_ms - report.timeline.iteration_ms).abs() < 1e-9);
}

/// Acceptance: `cornstarch tune <mllm> --cluster examples/clusters/
/// a40x8.json` (the real binary) and the programmatic
/// `PlanningService::plan()` answer the same request identically — the
/// CLI output must carry exactly the programmatic winner, its timing,
/// and the loaded cluster's pool.
#[test]
fn cli_cluster_file_and_programmatic_requests_answer_identically() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/clusters/a40x8.json"
    );
    let cluster = ClusterSpec::load(std::path::Path::new(path)).unwrap();
    assert_eq!(cluster.devices(), 8);
    assert_eq!(cluster.mem_budget_bytes(), 40_000_000_000);
    // same numbers as the A40 default, smaller pool
    assert_eq!(
        cluster.fingerprint(),
        ClusterSpec::a40_default().with_devices(8).fingerprint()
    );

    // the programmatic answer
    let spec = MllmSpec::vlm(Size::M, Size::S);
    let req = PlanRequest::default_for(spec)
        .cluster(cluster)
        .threads(2);
    let report = PlanningService::new().plan(&req).unwrap();
    let best = report.winner();
    assert!(report.plan.n_gpus <= 8);

    // the CLI answer, from the actual binary
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_cornstarch"))
        .args(["tune", "VLM-S", "--cluster", path, "--threads", "2"])
        .output()
        .expect("spawning the cornstarch binary");
    assert!(
        out.status.success(),
        "tune --cluster failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains(&best.candidate.label()),
        "CLI winner differs from programmatic winner {:?}:\n{text}",
        best.candidate.label()
    );
    assert!(
        text.contains(&format!("iteration {:.1} ms", best.iteration_ms)),
        "CLI iteration differs from programmatic {:.1} ms:\n{text}",
        best.iteration_ms
    );
    assert!(
        text.contains("(8 GPUs)"),
        "CLI did not plan for the cluster file's 8-device pool:\n{text}"
    );
    assert!(
        text.contains(&format!(
            "searched {} candidates",
            report.provenance.total_candidates
        )),
        "CLI searched a different space:\n{text}"
    );
}

/// Acceptance: a non-A40 spec (80 GB/device) readmits candidates the
/// A40's memory budget OOM-pruned.
#[test]
fn bigger_device_memory_readmits_oom_pruned_candidates() {
    let spec = MllmSpec::vlm(Size::M, Size::M);
    let mm = MultimodalModule::from_spec(&spec);
    let a40 = ClusterSpec::a40_default();
    let mut big = a40.clone();
    big.groups[0].device.name = "A100-80G".to_string();
    big.groups[0].device.mem_bytes = 80_000_000_000;

    // modeled peaks of the whole (unfiltered) space
    let mut unbounded = SearchSpace::for_cluster(&a40);
    unbounded.memory_budget_bytes = None;
    let all = enumerate(&mm, &unbounded);
    let peaks: Vec<u64> = all
        .iter()
        .map(|c| build_plan(&spec, c, &a40).peak_device_bytes())
        .collect();
    let a40_budget = a40.mem_budget_bytes();
    assert!(
        peaks.iter().any(|&p| p > a40_budget),
        "scenario must contain candidates the A40 budget OOM-prunes"
    );
    let readmitted = peaks
        .iter()
        .filter(|&&p| p > a40_budget && p <= big.groups[0].device.mem_bytes)
        .count();
    assert!(
        readmitted > 0,
        "an 80 GB device class must readmit some pruned candidate"
    );
    // the filtered enumerations agree exactly with the peak census
    let n_a40 = enumerate(&mm, &SearchSpace::for_cluster(&a40)).len();
    let n_big = enumerate(&mm, &SearchSpace::for_cluster(&big)).len();
    assert_eq!(n_a40 + readmitted, n_big);
}

/// Acceptance: the cluster's memory capacity measurably changes the
/// chosen plan — tightening the budget below the A40 winner's peak
/// forces a different winner.
#[test]
fn memory_capacity_changes_the_chosen_plan() {
    let spec = MllmSpec::vlm(Size::M, Size::M);
    let service = PlanningService::new();
    let base = service
        .plan(&PlanRequest::default_for(spec.clone()).threads(2))
        .unwrap();
    let winner_peak = base.winner().peak_mem_bytes;

    // there must be feasible candidates strictly below the winner's peak
    let mm = MultimodalModule::from_spec(&spec);
    let a40 = ClusterSpec::a40_default();
    let mut unbounded = SearchSpace::for_cluster(&a40);
    unbounded.memory_budget_bytes = None;
    let min_peak = enumerate(&mm, &unbounded)
        .iter()
        .map(|c| build_plan(&spec, c, &a40).peak_device_bytes())
        .min()
        .unwrap();
    assert!(
        min_peak < winner_peak,
        "premise: the makespan winner is not the min-memory plan"
    );

    let mut tight = a40;
    tight.groups[0].device.name = "tight".to_string();
    tight.groups[0].device.mem_bytes = winner_peak - 1;
    let tightened = service
        .plan(
            &PlanRequest::default_for(spec.clone())
                .cluster(tight)
                .threads(2),
        )
        .unwrap();
    assert_ne!(
        tightened.winner().candidate,
        base.winner().candidate,
        "a smaller memory budget must change the chosen plan"
    );
    assert!(tightened.winner().peak_mem_bytes < winner_peak);
    assert!(tightened.fits_budget());
    // and the A40 winner is strictly faster — the tight cluster paid for
    // its budget with iteration time
    assert!(
        base.winner().iteration_ms
            <= tightened.winner().iteration_ms + 1e-9
    );
}

/// Typed errors at the boundary: a bad cluster file and an infeasible
/// pool are distinguishable without string matching.
#[test]
fn facade_errors_are_typed() {
    match ClusterSpec::load(std::path::Path::new("/no/such/cluster.json"))
    {
        Err(PlanError::InvalidCluster(_)) => {}
        other => panic!("expected InvalidCluster, got {other:?}"),
    }
    let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::M))
        .devices(1)
        .threads(2);
    match PlanningService::new().plan(&req) {
        Err(PlanError::NoFeasiblePlan { mllm, devices }) => {
            assert_eq!(devices, 1);
            assert!(mllm.contains("VLM"));
        }
        other => panic!("expected NoFeasiblePlan, got {other:?}"),
    }
}

fn random_summary(g: &mut Gen) -> PlanSummary {
    let strategy = match g.usize(0, 3) {
        0 => Strategy::Cornstarch,
        1 => Strategy::Colocated,
        _ => Strategy::Replicated,
    };
    let n_enc = if strategy == Strategy::Replicated {
        0
    } else {
        g.usize(1, 3)
    };
    // Half the entries carry a heterogeneous assignment (one group per
    // chain), half are homogeneous (empty) — both must round-trip.
    let n_chains = if strategy == Strategy::Replicated {
        1
    } else {
        n_enc + 1
    };
    let chain_groups = if g.bool() {
        (0..n_chains).map(|_| g.usize(0, 3)).collect()
    } else {
        Vec::new()
    };
    PlanSummary {
        candidate: Candidate {
            strategy,
            enc_pps: (0..n_enc).map(|_| g.usize(1, 7)).collect(),
            llm_pp: g.usize(1, 7),
            tp: 1 << g.usize(0, 3),
            cp: 1 << g.usize(0, 2),
            num_microbatches: g.usize(1, 33),
            frozen: FrozenSetting::ALL[g.usize(0, 3)],
            chain_groups,
        },
        iteration_ms: g.usize(1, 1_000_000) as f64 / 10.0,
        throughput_per_gpu: g.usize(1, 10_000) as f64 / 1e4,
        n_gpus: g.usize(1, 65),
        peak_mem_bytes: g.rng.below(80_000_000_000),
        cp_algorithm: ["LPT", "Zigzag", "Ring", "none"][g.usize(0, 4)]
            .to_string(),
    }
}

/// Cache schema property: random v4 entries round-trip through disk
/// exactly; rewriting the same file as v3 degrades to an empty cache;
/// stripping an entry's cluster fingerprint rejects that entry.
#[test]
fn cache_v4_roundtrip_and_v3_degradation_property() {
    check("cache v3→v4 schema", 25, |g| {
        let mut path = std::env::temp_dir();
        path.push(format!(
            "cornstarch-api-cache-prop-{}-{:x}.json",
            std::process::id(),
            g.seed
        ));
        let _ = std::fs::remove_file(&path);

        let n_entries = g.usize(1, 4);
        let mut store = PlanCache::load(&path);
        let mut entries = Vec::new();
        for i in 0..n_entries {
            let depth = g.usize(1, 4);
            let frontier: Vec<PlanSummary> =
                (0..depth).map(|_| random_summary(g)).collect();
            let e = CacheEntry {
                signature: format!("sig-{i}-{:x}", g.seed),
                cluster: format!(
                    "n={}|mem={}",
                    g.usize(1, 65),
                    g.rng.below(1u64 << 40)
                ),
                frontier,
                top_k: depth,
                evaluated: g.usize(1, 100),
            };
            store.insert(e.clone());
            entries.push(e);
        }
        store.save().unwrap();

        // v4 round-trip is exact
        let loaded = PlanCache::load(&path);
        assert_eq!(loaded.len(), entries.len());
        for e in &entries {
            assert_eq!(
                loaded.lookup(&e.signature, &e.cluster),
                Some(e),
                "v4 entry did not round-trip"
            );
            // and the fingerprint is load-bearing: a different cluster
            // never answers
            assert!(loaded
                .lookup(&e.signature, "n=1|mem=1")
                .is_none());
        }

        let text = std::fs::read_to_string(&path).unwrap();

        // the same payload stamped v3 degrades to an empty cache
        let v3 = text.replace("\"version\":4", "\"version\":3");
        assert_ne!(text, v3);
        std::fs::write(&path, &v3).unwrap();
        assert!(
            PlanCache::load(&path).is_empty(),
            "a v3 file must degrade to empty, not serve v4 lookups"
        );

        // a v4 file whose entries lost their fingerprints drops them all
        let first = &entries[0];
        let mut stripped = text.clone();
        for e in &entries {
            stripped = stripped
                .replace(&format!("\"cluster\":\"{}\",", e.cluster), "");
        }
        assert!(!stripped.contains(&format!("\"{}\"", first.cluster)));
        std::fs::write(&path, &stripped).unwrap();
        assert!(
            PlanCache::load(&path).is_empty(),
            "fingerprint-less entries must be rejected, not defaulted"
        );

        let _ = std::fs::remove_file(&path);
    });
}

/// End-to-end cache degradation: a facade query that wrote a v4 cache
/// still answers (by re-searching) after the file is downgraded to v3,
/// and heals the file back to v4.
#[test]
fn facade_resurveys_after_v3_downgrade() {
    let mut path = std::env::temp_dir();
    path.push(format!(
        "cornstarch-api-cache-downgrade-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let cache = path.to_string_lossy().into_owned();

    let spec = MllmSpec::vlm(Size::M, Size::S);
    let req = PlanRequest::default_for(spec)
        .devices(8)
        .threads(2)
        .cache_file(&cache);
    let service = PlanningService::new();
    let first = service.plan(&req).unwrap();
    assert!(!first.provenance.cache_hit);
    assert!(service.plan(&req).unwrap().provenance.cache_hit);

    // downgrade the file to v3: the next query must re-search, not err.
    // The rewrite plays "external writer", so the process-wide store
    // must be told its in-memory image of this path is stale.
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, text.replace("\"version\":4", "\"version\":3"))
        .unwrap();
    cornstarch::tuner::PlanStore::invalidate_path(&cache);
    let after = service.plan(&req).unwrap();
    assert!(
        !after.provenance.cache_hit,
        "a v3 file must not satisfy a v4 lookup"
    );
    assert_eq!(after.winner(), first.winner());
    // and the store healed to v4
    assert!(std::fs::read_to_string(&path)
        .unwrap()
        .contains("\"version\":4"));
    let _ = std::fs::remove_file(&path);
}
