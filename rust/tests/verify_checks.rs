//! Integration tests for the static verifier ([`cornstarch::verify`]):
//! unmutated plans over both pool kinds verify clean, and a mutation per
//! lint class is caught by exactly its code — cycle injection (V001),
//! swapped fwd/bwd (V002), stripped 1F1B memory tokens (V003), a
//! doctored double-booked trace (V004), bad group assignments (V005),
//! inflated peak bytes (V006), dropped/duplicated cp token blocks
//! (V007), and frozen stages carrying backward cost (V008). Also holds
//! the golden human rendering and the byte-determinism contract of the
//! JSON form.

use cornstarch::api::{
    ClusterSpec, FleetPartition, PlanRequest, PlanningService,
};
use cornstarch::modality::Strategy;
use cornstarch::model::{MllmSpec, Size};
use cornstarch::pipeline::{onef1b_tasks, StageCost, StageGraph};
use cornstarch::sim::simulate;
use cornstarch::tuner::{Candidate, FrozenSetting};
use cornstarch::util::json::Json;
use cornstarch::verify::{
    self, resources, schedule, Code, Diagnostic, Severity, VerifyReport,
};

const REPORT_GOLDEN: &str = include_str!("golden/verify_report.txt");

fn spec() -> MllmSpec {
    MllmSpec::vlm(Size::S, Size::S)
}

fn small_request(cluster: ClusterSpec) -> PlanRequest {
    PlanRequest::default_for(spec()).cluster(cluster).threads(2)
}

fn chain_graph(stages: usize, fwd: f64, bwd: f64) -> StageGraph {
    let mut g = StageGraph::default();
    let costs = vec![StageCost { fwd_ms: fwd, bwd_ms: bwd }; stages];
    g.add_chain("llm", &costs, 0, &[]);
    g
}

fn error_codes(r: &VerifyReport) -> Vec<Code> {
    r.diagnostics
        .iter()
        .filter(|d| d.severity == Severity::Error)
        .map(|d| d.code)
        .collect()
}

#[test]
fn unmutated_plans_verify_clean_on_both_pool_kinds() {
    let pools =
        [ClusterSpec::a40_default().with_devices(8), ClusterSpec::a40_a100_demo()];
    for cluster in pools {
        let report = PlanningService::new()
            .plan(&small_request(cluster.clone()))
            .expect("planning a valid request succeeds");
        assert!(report.provenance.verifier_clean);
        let vr = verify::verify_plan(
            &report.plan,
            &cluster,
            Some(&report.winner().candidate),
            spec().llm_tokens(),
        );
        assert!(vr.is_clean(), "shipped plan failed lints:\n{}", vr.render());
    }
}

#[test]
fn v001_cycle_injection_is_caught() {
    let g = chain_graph(3, 1.0, 2.0);
    let m = 4;
    let mut tasks = onef1b_tasks(&g, m);
    // The last bwd transitively waits on the first fwd; closing the loop
    // the other way injects a cycle without touching task arity.
    let last = tasks.len() - 1;
    tasks[0].deps.push((last, 0.0));
    let r = verify::verify_schedule(&tasks, &g, m);
    assert_eq!(error_codes(&r), vec![Code::V001], "{}", r.render());
    assert!(r.diagnostics[0].message.contains("cycle"));
}

#[test]
fn v001_out_of_range_dependency_is_caught() {
    let g = chain_graph(2, 1.0, 1.0);
    let mut tasks = onef1b_tasks(&g, 2);
    let n = tasks.len();
    tasks[1].deps.push((n + 7, 0.0));
    let r = verify::verify_schedule(&tasks, &g, 2);
    assert_eq!(error_codes(&r), vec![Code::V001], "{}", r.render());
    assert!(r.diagnostics[0].message.contains("out of range"));
}

#[test]
fn v002_bwd_released_before_its_fwd_is_caught() {
    let g = chain_graph(2, 1.0, 1.0);
    let m = 4;
    let n = g.nodes.len();
    let mut tasks = onef1b_tasks(&g, m);
    // bwd(stage 1, mb 0): stripping its deps frees it to run at t=0,
    // before its matching forward has produced activations.
    let bad = m * n + 1;
    assert_eq!(tasks[bad].stage, 1);
    assert_eq!(tasks[bad].microbatch, 0);
    tasks[bad].deps.clear();
    let r = verify::verify_schedule(&tasks, &g, m);
    let codes = error_codes(&r);
    assert!(codes.contains(&Code::V002), "{}", r.render());
    assert!(codes.iter().all(|&c| c == Code::V002), "{}", r.render());
}

#[test]
fn v003_stripped_memory_tokens_are_caught() {
    let g = chain_graph(2, 1.0, 1.0);
    let m = 6;
    let n = g.nodes.len();
    let mut tasks = onef1b_tasks(&g, m);
    // Forward tasks occupy ids [0, m*n); any dep at or past that split is
    // a 1F1B memory token. Removing them lets every microbatch pile up.
    let split = m * n;
    for t in tasks.iter_mut().take(split) {
        t.deps.retain(|&(d, _)| d < split);
    }
    let r = verify::verify_schedule(&tasks, &g, m);
    let codes = error_codes(&r);
    assert!(codes.contains(&Code::V003), "{}", r.render());
    assert!(codes.iter().all(|&c| c == Code::V003), "{}", r.render());
}

#[test]
fn v004_doctored_trace_double_books_a_device() {
    let g = chain_graph(2, 1.0, 1.0);
    let m = 4;
    let n = g.nodes.len();
    let tasks = onef1b_tasks(&g, m);
    let mut trace = simulate(&tasks).trace;
    // fwd(stage 0, mb 1) sits at task id n; drag its start back into the
    // interval fwd(stage 0, mb 0) occupies on the same device.
    let victim = n;
    assert_eq!(trace[victim].stage, 0);
    assert_eq!(trace[victim].microbatch, 1);
    trace[victim].start_ms = trace[0].start_ms + 0.25;
    let diags = schedule::check_trace(&trace, &g, m);
    assert!(!diags.is_empty());
    assert!(diags.iter().all(|d| d.code == Code::V004));
    assert!(diags[0].subject.starts_with("device"));
}

#[test]
fn v005_assignment_rules_migrated_from_space() {
    // `Candidate::assignment_is_valid` used to answer these with a bare
    // bool; the verifier's V005 lints now hold the same contract.
    let homo = ClusterSpec::a40_default();
    let demo = ClusterSpec::a40_a100_demo();
    let base = Candidate {
        strategy: Strategy::Cornstarch,
        enc_pps: vec![1, 2],
        llm_pp: 2,
        tp: 1,
        cp: 1,
        num_microbatches: 8,
        frozen: FrozenSetting::Paper,
        chain_groups: Vec::new(),
    };
    let with = |groups: Vec<usize>| Candidate {
        chain_groups: groups,
        ..base.clone()
    };

    // Empty assignment means "the single group of a homogeneous pool".
    assert!(verify::verify_candidate(&base, &homo).is_clean());

    // In range on the two-group pool, out of range on the one-group pool.
    assert!(verify::verify_candidate(&with(vec![0, 1, 1]), &demo).is_clean());
    let r = verify::verify_candidate(&with(vec![0, 1, 1]), &homo);
    assert!(!r.is_clean());
    assert!(error_codes(&r).iter().all(|&c| c == Code::V005));

    // Arity: three chains (two encoders + LLM) need three entries.
    let r = verify::verify_candidate(&with(vec![0, 1]), &demo);
    assert_eq!(error_codes(&r), vec![Code::V005]);

    // Colocated encoders must share one group.
    let colo = |groups: Vec<usize>| Candidate {
        strategy: Strategy::Colocated,
        chain_groups: groups,
        ..base.clone()
    };
    let r = verify::verify_candidate(&colo(vec![0, 1, 1]), &demo);
    assert_eq!(error_codes(&r), vec![Code::V005]);
    assert!(r.diagnostics[0].message.contains("split across groups"));
    assert!(verify::verify_candidate(&colo(vec![1, 1, 0]), &demo).is_clean());

    // Replicated has exactly one chain.
    let repl = |groups: Vec<usize>| Candidate {
        strategy: Strategy::Replicated,
        enc_pps: Vec::new(),
        chain_groups: groups,
        ..base.clone()
    };
    assert!(verify::verify_candidate(&repl(vec![1]), &demo).is_clean());
    let r = verify::verify_candidate(&repl(vec![0, 0]), &demo);
    assert_eq!(error_codes(&r), vec![Code::V005]);

    // Over-capacity: 2 LLM stages of tp×cp = 4 GPUs each don't fit a
    // 4-device group even with sane indices.
    let fat = Candidate {
        enc_pps: vec![1],
        tp: 2,
        cp: 2,
        chain_groups: vec![0, 1],
        ..base.clone()
    };
    let r = verify::verify_candidate(&fat, &demo);
    assert_eq!(error_codes(&r), vec![Code::V005]);
    assert!(r.diagnostics[0].message.contains("GPUs assigned"));
}

#[test]
fn v005_v006_plan_mutations_are_caught() {
    let cluster = ClusterSpec::a40_default().with_devices(8);
    let report = PlanningService::new()
        .plan(&small_request(cluster.clone()))
        .expect("planning a valid request succeeds");

    // Bad group index: reported, never indexed into the cluster.
    let mut bad_group = report.plan.clone();
    bad_group.stage_groups[0] = 9;
    let r = verify::verify_plan(&bad_group, &cluster, None, spec().llm_tokens());
    assert_eq!(error_codes(&r), vec![Code::V005], "{}", r.render());

    // Inflated peak bytes: 10 TiB of params blows any A40 budget.
    let mut oom = report.plan.clone();
    oom.stage_mem[0].param_bytes += 10u64 << 40;
    let r = verify::verify_plan(&oom, &cluster, None, spec().llm_tokens());
    assert_eq!(error_codes(&r), vec![Code::V006], "{}", r.render());
}

#[test]
fn v007_dropped_and_duplicated_cp_blocks_are_caught() {
    // The real cp=2 distribution over the tuner's workload is covering.
    assert!(resources::check_cp(spec().llm_tokens(), 2).is_empty());
    // cp <= 1 trivially distributes nothing.
    assert!(resources::check_cp(spec().llm_tokens(), 1).is_empty());

    // Dropped block: fewer assignments than token blocks.
    let short = vec![0usize; 9];
    let r = VerifyReport::from_diagnostics(resources::check_cp_assignment(
        10, 2, &short,
    ));
    assert_eq!(error_codes(&r), vec![Code::V007]);

    // Out-of-range rank: those blocks are silently lost at execution.
    let bad_rank = vec![0, 1, 0, 1, 5, 0, 1, 0, 1, 0];
    let r = VerifyReport::from_diagnostics(resources::check_cp_assignment(
        10, 2, &bad_rank,
    ));
    assert_eq!(error_codes(&r), vec![Code::V007]);
    assert!(r.diagnostics[0].message.contains("rank 5"));
}

#[test]
fn v008_frozen_stage_with_bwd_cost_warns_but_stays_clean() {
    let cluster = ClusterSpec::a40_default().with_devices(8);
    let report = PlanningService::new()
        .plan(&small_request(cluster.clone()))
        .expect("planning a valid request succeeds");
    // Claim the plan is all-frozen while its stages were costed with
    // live backward passes: the cost model and the policy now disagree.
    let mut frosty = report.winner().candidate.clone();
    frosty.frozen = FrozenSetting::AllFrozen;
    let r = verify::verify_plan(
        &report.plan,
        &cluster,
        Some(&frosty),
        spec().llm_tokens(),
    );
    assert!(r.is_clean(), "V008 is Warn severity: {}", r.render());
    assert!(r.warnings() > 0, "{}", r.render());
    assert!(r
        .diagnostics
        .iter()
        .filter(|d| d.severity == Severity::Warn)
        .all(|d| d.code == Code::V008));
}

#[test]
fn fleet_partition_lints_split_errors_from_idle_warnings() {
    let demo = ClusterSpec::a40_a100_demo();

    // Full coverage: clean, not even a warning.
    let full = FleetPartition { slices: vec![vec![4, 0], vec![0, 4]] };
    let r = verify::verify_partition(&full, &demo);
    assert!(r.is_clean() && r.warnings() == 0, "{}", r.render());

    // A group oversubscribed across tenants is an Error.
    let over = FleetPartition { slices: vec![vec![4, 2], vec![1, 2]] };
    let r = verify::verify_partition(&over, &demo);
    assert_eq!(error_codes(&r), vec![Code::V005], "{}", r.render());

    // Idle headroom is visible but does not block the carve.
    let idle = FleetPartition { slices: vec![vec![2, 4]] };
    let r = verify::verify_partition(&idle, &demo);
    assert!(r.is_clean());
    assert_eq!(r.warnings(), 1);
    assert!(r.diagnostics[0].message.contains("idle headroom"));

    // A slice not shaped to the pool's group list is an Error.
    let misshapen = FleetPartition { slices: vec![vec![4]] };
    assert!(!verify::verify_partition(&misshapen, &demo).is_clean());
}

#[test]
fn report_rendering_matches_golden() {
    let report = VerifyReport::from_diagnostics(vec![
        Diagnostic::new(
            Code::V008,
            "enc:vision[0]",
            "all-frozen config, stage carries 12.000 ms of bwd cost",
        ),
        Diagnostic::new(
            Code::V006,
            "llm[0]",
            "peak 91.00 GiB exceeds the 44.00 GiB budget of group 0 (A40)",
        ),
        Diagnostic::new(
            Code::V001,
            "",
            "dependency cycle of 3 task(s): fwd s0 mb0 waits for bwd s2 mb1",
        ),
    ]);
    assert_eq!(report.render(), REPORT_GOLDEN);
}

#[test]
fn verify_json_is_byte_identical_across_runs() {
    let run = || {
        let cluster = ClusterSpec::a40_default().with_devices(8);
        let report = PlanningService::new()
            .plan(&small_request(cluster.clone()))
            .expect("planning a valid request succeeds");
        verify::verify_plan(
            &report.plan,
            &cluster,
            Some(&report.winner().candidate),
            spec().llm_tokens(),
        )
        .to_json()
        .render()
    };
    let first = run();
    let second = run();
    assert_eq!(first, second);
    let parsed = Json::parse(&first).expect("verify JSON parses");
    assert_eq!(parsed.get("clean").and_then(Json::as_bool), Some(true));
}
