"""L2 correctness: modular MLLM stages, flat-param layout, backward
programs, optimizer — everything `aot.py` exports."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref

CFG = M.CONFIGS["tiny"]
CFG_VA = M.CONFIGS["tiny_va"]


def init_all(cfg, seed=0):
    return {c.name: jnp.asarray(M.init_flat(c.layout, seed + i))
            for i, c in enumerate(M.components(cfg))
            if c.shares_params_with is None}


def sample_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    text = jnp.asarray(rng.integers(0, cfg.llm.vocab, cfg.text_len), jnp.int32)
    mods = {e.name: jnp.asarray(rng.normal(size=(e.n_tokens, e.d_input)),
                                jnp.float32) for e in cfg.encoders}
    # next-token labels over the spliced layout (mirrors rust train::data)
    labels = np.full(cfg.total_tokens, -1, dtype=np.int32)
    tpos = [i for (kind, s, e, _) in cfg.segments() if kind == "text"
            for i in range(s, e)]
    for j in range(len(tpos) - 1):
        labels[tpos[j]] = int(text[j + 1])
    return text, mods, jnp.asarray(labels)


class TestLayout:
    def test_layout_offsets_contiguous(self):
        for c in M.components(CFG_VA):
            off = 0
            for name, o, shape in c.layout.entries:
                assert o == off
                off += int(np.prod(shape)) if shape else 1
            assert off == c.layout.total

    def test_layout_slice_roundtrip(self):
        lo = M.encoder_layout(CFG.encoders[0])
        flat = jnp.arange(lo.total, dtype=jnp.float32)
        w = lo.slice(flat, "in_proj.w")
        assert w.shape == (48, 48)
        assert float(w[0, 0]) == 0.0
        b = lo.slice(flat, "in_proj.b")
        assert float(b[0]) == 48 * 48

    def test_head_shares_last_stage_layout(self):
        comps = {c.name: c for c in M.components(CFG)}
        assert comps["llm:head"].shares_params_with == "llm:1"
        assert comps["llm:head"].layout.total == comps["llm:1"].layout.total

    def test_param_counts_scale(self):
        tiny = sum(c.layout.total for c in M.components(CFG)
                   if c.shares_params_with is None)
        mini = sum(c.layout.total for c in M.components(M.CONFIGS["mini"])
                   if c.shares_params_with is None)
        e2e = sum(c.layout.total for c in M.components(M.CONFIGS["e2e100m"])
                  if c.shares_params_with is None)
        assert tiny < 1_000_000
        assert 20_000_000 < mini < 80_000_000
        assert 85_000_000 < e2e < 160_000_000


class TestForward:
    def test_component_shapes(self):
        flats = init_all(CFG)
        text, mods, labels = sample_batch(CFG)
        bits, pos = CFG.bits_pos()
        e = CFG.encoders[0]
        feats = M.encoder_fwd(e)(flats["enc:vision"], mods["vision"])
        assert feats.shape == (e.n_tokens, e.d_model)
        mh = M.projector_fwd(e, CFG.llm)(flats["proj:vision"], feats)
        assert mh.shape == (e.n_tokens, CFG.llm.d_model)
        h = M.llm_stage_fwd(CFG, 0)(flats["llm:0"], text, mh, bits, pos)
        assert h.shape == (CFG.total_tokens, CFG.llm.d_model)
        h = M.llm_stage_fwd(CFG, 1)(flats["llm:1"], h, bits, pos)
        loss = M.llm_head_fwd(CFG)(flats["llm:1"], h, labels)
        assert loss.shape == ()
        assert np.isfinite(float(loss))

    def test_loss_near_log_vocab_at_init(self):
        flats = init_all(CFG)
        text, mods, labels = sample_batch(CFG)
        loss = M.mllm_forward(CFG, flats, text, mods, labels)
        assert abs(float(loss) - np.log(CFG.llm.vocab)) < 1.0

    def test_two_encoder_model(self):
        flats = init_all(CFG_VA)
        text, mods, labels = sample_batch(CFG_VA)
        loss = M.mllm_forward(CFG_VA, flats, text, mods, labels)
        assert np.isfinite(float(loss))

    def test_segments_cover_sequence(self):
        for cfg in (CFG, CFG_VA, M.CONFIGS["mini"]):
            segs = cfg.segments()
            assert segs[0][1] == 0
            for (_, _, e1, _), (_, s2, _, _) in zip(segs, segs[1:]):
                assert e1 == s2
            assert segs[-1][2] == cfg.total_tokens

    def test_bits_pos_match_ref_builder(self):
        bits, pos = CFG.bits_pos()
        # tiny: text[0:4], vision[4:12], text[12:32] == EE layout
        bits2, pos2 = ref.make_bits_ee([4, 20], [8])
        np.testing.assert_array_equal(np.asarray(bits), np.asarray(bits2))
        np.testing.assert_array_equal(np.asarray(pos), np.asarray(pos2))


class TestBackward:
    def test_bwd_matches_whole_model_grad(self):
        """Chained per-stage bwd artifacts == jax.grad of the monolithic
        model: the pipeline executor's numerics contract."""
        flats = init_all(CFG)
        text, mods, labels = sample_batch(CFG)
        bits, pos = CFG.bits_pos()
        comps = {c.name: c for c in M.components(CFG)}

        # forward chain, saving stage inputs
        e = CFG.encoders[0]
        feats = comps["enc:vision"].fwd(flats["enc:vision"], mods["vision"])
        mh = comps["proj:vision"].fwd(flats["proj:vision"], feats)
        h0 = comps["llm:0"].fwd(flats["llm:0"], text, mh, bits, pos)
        h1 = comps["llm:1"].fwd(flats["llm:1"], h0, bits, pos)

        # backward chain (all trainable -> bwd everywhere)
        dflat_head, dh1 = M.make_bwd(comps["llm:head"], True)(
            flats["llm:1"], h1, labels)
        dflat1, dh0 = M.make_bwd(comps["llm:1"], True)(
            flats["llm:1"], h0, bits, pos, dh1)
        dflat0, dmh = M.make_bwd(comps["llm:0"], True)(
            flats["llm:0"], text, mh, bits, pos, dh0)
        dflat_proj, dfeats = M.make_bwd(comps["proj:vision"], True)(
            flats["proj:vision"], feats, dmh)
        dflat_enc, _ = M.make_bwd(comps["enc:vision"], True)(
            flats["enc:vision"], mods["vision"], dfeats)

        # oracle: grad of the whole model wrt each flat
        def whole(f_enc, f_proj, f_l0, f_l1):
            return M.mllm_forward(
                CFG, {"enc:vision": f_enc, "proj:vision": f_proj,
                      "llm:0": f_l0, "llm:1": f_l1}, text, mods, labels)

        g = jax.grad(whole, argnums=(0, 1, 2, 3))(
            flats["enc:vision"], flats["proj:vision"], flats["llm:0"],
            flats["llm:1"])
        np.testing.assert_allclose(np.asarray(dflat_enc), np.asarray(g[0]),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(dflat_proj), np.asarray(g[1]),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(dflat0), np.asarray(g[2]),
                                   atol=1e-4)
        # llm:1 receives grads from both its own stage AND the head
        np.testing.assert_allclose(np.asarray(dflat1 + dflat_head),
                                   np.asarray(g[3]), atol=1e-4)

    def test_bwdin_equals_bwd_input_part(self):
        """The frozen path (bwdin) returns exactly the input-grad slice of
        the full backward — the §4.2 '1×T_fwd' program."""
        flats = init_all(CFG)
        text, mods, labels = sample_batch(CFG)
        bits, pos = CFG.bits_pos()
        comps = {c.name: c for c in M.components(CFG)}
        h0 = comps["llm:0"].fwd(flats["llm:0"], text,
                                comps["proj:vision"].fwd(
                                    flats["proj:vision"],
                                    comps["enc:vision"].fwd(
                                        flats["enc:vision"], mods["vision"])),
                                bits, pos)
        g = jnp.ones((CFG.total_tokens, CFG.llm.d_model), jnp.float32)
        full = M.make_bwd(comps["llm:1"], True)(flats["llm:1"], h0, bits,
                                                pos, g)
        only = M.make_bwd(comps["llm:1"], False)(flats["llm:1"], h0, bits,
                                                 pos, g)
        np.testing.assert_allclose(np.asarray(full[1]), np.asarray(only[0]),
                                   atol=0)


class TestOptimizer:
    def test_adamw_decreases_loss_on_quadratic(self):
        target = jnp.asarray(np.linspace(-1, 1, 32), jnp.float32)
        flat = jnp.zeros(32)
        m = jnp.zeros(32)
        v = jnp.zeros(32)
        for step in range(1, 200):
            g = 2 * (flat - target)
            flat, m, v = M.adamw_update(flat, g, m, v, float(step), 0.05)
        assert float(jnp.max(jnp.abs(flat - target))) < 0.15

    def test_adamw_bias_correction_first_step(self):
        g = jnp.ones(4)
        flat, m, v = M.adamw_update(jnp.zeros(4), g, jnp.zeros(4),
                                    jnp.zeros(4), 1.0, 0.1)
        # mhat = g, vhat = g^2 -> step ~= -lr * 1.0
        np.testing.assert_allclose(np.asarray(flat), -0.1 * np.ones(4),
                                   atol=1e-5)

    def test_init_flat_deterministic(self):
        lo = M.encoder_layout(CFG.encoders[0])
        a = M.init_flat(lo, 7)
        b = M.init_flat(lo, 7)
        np.testing.assert_array_equal(a, b)

    def test_init_flat_ln_scales_are_one(self):
        lo = M.encoder_layout(CFG.encoders[0])
        flat = jnp.asarray(M.init_flat(lo, 3))
        s = lo.slice(flat, "enc.blocks.0.ln1.scale")
        np.testing.assert_array_equal(np.asarray(s), np.ones(48, np.float32))
        b = lo.slice(flat, "enc.blocks.0.ln1.bias")
        np.testing.assert_array_equal(np.asarray(b), np.zeros(48, np.float32))


class TestTraining:
    def test_few_steps_reduce_loss(self):
        """Projector-only training (the paper's default setting) on a fixed
        batch reduces loss — the frozen path still propagates grads
        through the LLM (the 1x rule) to reach the projector."""
        flats = init_all(CFG)
        text, mods, labels = sample_batch(CFG)

        def loss_fn(f_proj):
            d = dict(flats)
            d["proj:vision"] = f_proj
            return M.mllm_forward(CFG, d, text, mods, labels)

        f = flats["proj:vision"]
        m = jnp.zeros_like(f)
        v = jnp.zeros_like(f)
        l0 = float(loss_fn(f))
        for step in range(1, 25):
            g = jax.grad(loss_fn)(f)
            f, m, v = M.adamw_update(f, g, m, v, float(step), 1e-2)
        l1 = float(loss_fn(f))
        assert l1 < l0
