"""AOT export path: manifest grammar, artifact files, HLO-text sanity."""

import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def tiny_export(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = []
    aot.export_model(M.CONFIGS["tiny"], out, manifest)
    aot.export_attn(out, manifest, sizes=((64, 2, 16),))
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return out, manifest


class TestManifest:
    def test_records_present(self, tiny_export):
        _, manifest = tiny_export
        kinds = {line.split()[0] for line in manifest if line and
                 not line.startswith("#")}
        assert {"model", "tokens", "segment", "component", "params",
                "artifact", "edge", "attn"} <= kinds

    def test_artifact_files_exist(self, tiny_export):
        out, manifest = tiny_export
        for line in manifest:
            parts = line.split()
            if parts and parts[0] == "artifact":
                assert os.path.exists(os.path.join(out, parts[3])), parts[3]
            if parts and parts[0] == "params":
                assert os.path.exists(os.path.join(out, parts[2]))

    def test_param_file_sizes(self, tiny_export):
        out, manifest = tiny_export
        for line in manifest:
            parts = line.split()
            if parts and parts[0] == "params":
                n = int(parts[3])
                sz = os.path.getsize(os.path.join(out, parts[2]))
                assert sz == 4 * n

    def test_hlo_text_has_entry(self, tiny_export):
        out, manifest = tiny_export
        checked = 0
        for line in manifest:
            parts = line.split()
            if parts and parts[0] == "artifact":
                with open(os.path.join(out, parts[3])) as f:
                    text = f.read()
                assert "ENTRY" in text and "HloModule" in text
                checked += 1
        assert checked >= 13  # 4 comps x (fwd,bwd,bwdin) + upds

    def test_io_specs_parse(self, tiny_export):
        _, manifest = tiny_export
        for line in manifest:
            parts = line.split()
            if parts and parts[0] == "artifact":
                ins = [kv for kv in parts if kv.startswith("ins=")][0][4:]
                for spec in ins.split(";"):
                    name, dt, dims = spec.split(":")
                    assert dt in ("f32", "i32")
                    assert dims == "_" or all(
                        int(d) > 0 for d in dims.split("x"))

    def test_edges_form_dag_to_head(self, tiny_export):
        _, manifest = tiny_export
        edges = [(l.split()[1], l.split()[2]) for l in manifest
                 if l.startswith("edge ")]
        dsts = {d for _, d in edges}
        assert "llm:head" in dsts
        # every encoder chain reaches llm:0
        assert ("proj:vision", "llm:0") in edges

    def test_segment_bits_match_config(self, tiny_export):
        _, manifest = tiny_export
        segs = [l.split() for l in manifest if l.startswith("segment ")]
        cfg_segs = M.CONFIGS["tiny"].segments()
        assert len(segs) == len(cfg_segs)
        for got, want in zip(segs, cfg_segs):
            assert (got[1], int(got[2]), int(got[3]), int(got[4])) == want


class TestHloRoundTrip:
    def test_deterministic_param_init(self, tiny_export):
        out, _ = tiny_export
        a = np.fromfile(os.path.join(out, "tiny/params/llm_0.f32.bin"),
                        dtype=np.float32)
        b = M.init_flat(M.llm_stage_layout(M.CONFIGS["tiny"], 0),
                        seed=hash("llm:0") % (2**31))
        np.testing.assert_array_equal(a, b)

    def test_hlo_text_is_64bit_id_safe(self, tiny_export):
        """The whole point of text interchange: no serialized protos."""
        out, manifest = tiny_export
        rel = next(l.split()[3] for l in manifest if l.startswith("artifact"))
        with open(os.path.join(out, rel)) as f:
            head = f.read(200)
        assert head.lstrip().startswith("HloModule")
