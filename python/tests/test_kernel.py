"""L1 correctness: Pallas BAM attention kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot path. hypothesis
sweeps shapes, block sizes, and mask layouts; everything asserts
allclose against ``kernels/ref.py``.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import bam_attention as K


def _rand_qkv(rng, t, h, d, tk=None):
    tk = tk or t
    q = jnp.asarray(rng.normal(size=(t, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(tk, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(tk, h, d)), jnp.float32)
    return q, k, v


def _rand_bits(rng, t, n_modalities=2):
    """Random BAM vector: contiguous modality segments inside text."""
    kinds = rng.integers(0, n_modalities + 1, size=t)
    kinds.sort()  # segments contiguous, text interleaved below
    rng.shuffle(kinds[: t // 2])
    text_bits = ref.TEXT_BIT
    for m in range(n_modalities):
        text_bits |= 1 << (m + 1)
    bits = np.where(kinds == 0, text_bits, 1 << kinds).astype(np.int32)
    return jnp.asarray(bits), jnp.arange(t, dtype=jnp.int32)


def assert_close(a, b, atol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol,
                               rtol=1e-4)


class TestKernelVsRef:
    def test_ee_layout_basic(self):
        rng = np.random.default_rng(0)
        q, k, v = _rand_qkv(rng, 37, 2, 16)
        bits, pos = ref.make_bits_ee([5, 10, 8], [6, 8])
        out = K.bam_attention_fwd_kernel(q, k, v, bits, pos, bits, pos, 16, 16)
        assert_close(out, ref.attention_ref(q, k, v, bits, pos, bits, pos))

    def test_ep_layout_basic(self):
        rng = np.random.default_rng(1)
        q, k, v = _rand_qkv(rng, 48, 4, 8)
        bits, pos = ref.make_bits_ep(32, [10, 6])
        out = K.bam_attention_fwd_kernel(q, k, v, bits, pos, bits, pos)
        assert_close(out, ref.attention_ref(q, k, v, bits, pos, bits, pos))

    def test_pure_causal_text(self):
        """All-text BAM degenerates to plain causal attention."""
        rng = np.random.default_rng(2)
        t = 33
        q, k, v = _rand_qkv(rng, t, 2, 8)
        bits = jnp.full((t,), ref.TEXT_BIT, jnp.int32)
        pos = jnp.arange(t, dtype=jnp.int32)
        out = K.bam_attention_fwd_kernel(q, k, v, bits, pos, bits, pos, 8, 8)
        assert_close(out, ref.attention_ref(q, k, v, bits, pos, bits, pos))

    def test_single_modality_block_is_full_attention(self):
        rng = np.random.default_rng(3)
        t = 16
        q, k, v = _rand_qkv(rng, t, 2, 8)
        bits = jnp.full((t,), 2, jnp.int32)  # one modality, no text
        pos = jnp.arange(t, dtype=jnp.int32)
        out = K.bam_attention_fwd_kernel(q, k, v, bits, pos, bits, pos)
        # full bidirectional softmax attention
        ref_out = ref.attention_ref(q, k, v, bits, pos, bits, pos)
        assert_close(out, ref_out)
        full = jax.nn.softmax(
            jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(8.0), axis=-1)
        direct = jnp.einsum("hqk,khd->qhd", full, v)
        assert_close(out, direct)

    @settings(max_examples=25, deadline=None)
    @given(
        t=st.integers(3, 96),
        h=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([4, 8, 16]),
        blk=st.sampled_from([8, 16, 32, 128]),
        seed=st.integers(0, 2**16),
        n_mod=st.integers(1, 4),
    )
    def test_hypothesis_shapes_and_masks(self, t, h, d, blk, seed, n_mod):
        rng = np.random.default_rng(seed)
        q, k, v = _rand_qkv(rng, t, h, d)
        bits, pos = _rand_bits(rng, t, n_mod)
        out = K.bam_attention_fwd_kernel(q, k, v, bits, pos, bits, pos,
                                         blk, blk)
        assert_close(out, ref.attention_ref(q, k, v, bits, pos, bits, pos))

    @settings(max_examples=10, deadline=None)
    @given(t=st.integers(8, 48), seed=st.integers(0, 2**16))
    def test_cp_shard_equivalence(self, t, seed):
        """A rank holding an arbitrary query subset against gathered K/V
        computes exactly the matching rows of the full result — the
        correctness contract of §4.3's token distribution."""
        rng = np.random.default_rng(seed)
        q, k, v = _rand_qkv(rng, t, 2, 8)
        bits, pos = _rand_bits(rng, t)
        full = K.bam_attention_fwd_kernel(q, k, v, bits, pos, bits, pos, 8, 8)
        idx = rng.permutation(t)[: max(1, t // 3)]
        idx_j = jnp.asarray(np.sort(idx))
        shard = K.bam_attention_fwd_kernel(
            q[idx_j], k, v, bits[idx_j], pos[idx_j], bits, pos, 8, 8)
        assert_close(shard, full[idx_j])

    def test_padding_tail_rows_are_sliced_off(self):
        """T not divisible by block: output shape is exact, tail is real."""
        rng = np.random.default_rng(5)
        t = 19
        q, k, v = _rand_qkv(rng, t, 1, 8)
        bits, pos = _rand_bits(rng, t)
        out = K.bam_attention_fwd_kernel(q, k, v, bits, pos, bits, pos, 16, 16)
        assert out.shape == (t, 1, 8)
        assert_close(out, ref.attention_ref(q, k, v, bits, pos, bits, pos))

    def test_no_nans_on_adversarial_bits(self):
        """Isolated modality token (segment of length 1) still attends
        itself; no NaN rows ever."""
        rng = np.random.default_rng(6)
        t = 9
        q, k, v = _rand_qkv(rng, t, 1, 4)
        bits = jnp.asarray([3, 2, 3, 4, 3, 8, 3, 3, 3], jnp.int32)
        pos = jnp.arange(t, dtype=jnp.int32)
        out = K.bam_attention_fwd_kernel(q, k, v, bits, pos, bits, pos, 4, 4)
        assert not bool(jnp.any(jnp.isnan(out)))
        assert_close(out, ref.attention_ref(q, k, v, bits, pos, bits, pos))


class TestKernelGradients:
    @settings(max_examples=8, deadline=None)
    @given(t=st.integers(4, 32), seed=st.integers(0, 2**16))
    def test_custom_vjp_matches_ref_grads(self, t, seed):
        rng = np.random.default_rng(seed)
        q, k, v = _rand_qkv(rng, t, 2, 8)
        bits, pos = _rand_bits(rng, t)

        def f_k(q, k, v):
            return jnp.sum(K.bam_attention(q, k, v, bits, pos, bits, pos) ** 2)

        def f_r(q, k, v):
            return jnp.sum(ref.attention_ref(q, k, v, bits, pos, bits, pos) ** 2)

        gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            assert_close(a, b, atol=1e-4)


class TestWorkloads:
    def test_row_sums_match_mask(self):
        rng = np.random.default_rng(7)
        for _ in range(5):
            t = int(rng.integers(4, 64))
            bits, pos = _rand_bits(rng, t)
            w = ref.token_workloads(bits, pos)
            mask = ref.can_attend(bits, pos, bits, pos)
            np.testing.assert_array_equal(
                np.asarray(w), np.asarray(mask).sum(axis=1))

    def test_self_attention_always_allowed(self):
        rng = np.random.default_rng(8)
        bits, pos = _rand_bits(rng, 40)
        mask = np.asarray(ref.can_attend(bits, pos, bits, pos))
        assert mask.diagonal().all()

    def test_vmem_estimate_within_budget(self):
        """Perf-pass guard: default blocks fit a 16MB VMEM budget at the
        sizes the paper's CP experiments use per rank (64k/8 ranks, d=128)."""
        assert K.vmem_bytes(K.DEFAULT_BLK_Q, K.DEFAULT_BLK_K, 128,
                            8192) <= 16 * 2**20
