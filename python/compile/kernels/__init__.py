"""L1 Pallas kernels + pure-jnp oracle.

Import submodules explicitly (``from compile.kernels import ref,
bam_attention``); the kernel entrypoints live on
``bam_attention.bam_attention`` (custom-vjp wrapped) and
``bam_attention.bam_attention_fwd_kernel``.
"""
from . import ref  # noqa: F401
from . import bam_attention  # noqa: F401
