"""Pure-jnp oracle for Bitfield Attention Mask (BAM) attention.

This module is the *normative* definition of BAM semantics for the whole
repo (L1 Pallas kernel, L2 model, L3 rust `bam` module all match it, and
DESIGN.md documents the same rule):

Token ``i`` carries an integer bitfield ``bits[i]``; bit 0 is the text
modality, bits ``1..`` are modality encoders (paper: 64-bit, ~60 usable
modalities; this artifact build carries them as int32 lanes — see
DESIGN.md "Hardware-Adaptation").

``can_attend(i, j)``:

* text token (bit0 of ``bits[i]`` set): attends ``j`` iff ``pos[j] <=
  pos[i]`` and ``bits[i] & bits[j] != 0`` — causal over every modality its
  field enables (the paper's t6..t8 example).
* modality token: attends ``j`` iff ``bits[j] == bits[i]`` — full
  bidirectional attention within its own modality segment (ViT/Whisper
  encoder-output style).

Positions are explicit so that a context-parallel rank holding an
arbitrary subset of query tokens can still evaluate the predicate against
the full gathered key/value set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

TEXT_BIT = 1  # bit 0


def can_attend(bits_q: jax.Array, pos_q: jax.Array, bits_k: jax.Array,
               pos_k: jax.Array) -> jax.Array:
    """Materialize the [Tq, Tk] boolean BAM mask from bitfield vectors.

    Args:
      bits_q: int32[Tq] bitfields of query tokens.
      pos_q:  int32[Tq] global positions of query tokens.
      bits_k: int32[Tk] bitfields of key tokens.
      pos_k:  int32[Tk] global positions of key tokens.

    Returns:
      bool[Tq, Tk] where ``[i, j]`` is True iff query i attends key j.
    """
    bq = bits_q[:, None]
    pq = pos_q[:, None]
    bk = bits_k[None, :]
    pk = pos_k[None, :]
    is_text = (bq & TEXT_BIT) != 0
    text_rule = (pk <= pq) & ((bq & bk) != 0)
    modality_rule = bk == bq
    return jnp.where(is_text, text_rule, modality_rule)


def token_workloads(bits: jax.Array, pos: jax.Array) -> jax.Array:
    """Per-token attention workload W_i = row-sum of the BAM mask.

    The rust ``bam::workloads`` must produce identical numbers (tested via
    the ``table4``/``fig12`` fixtures).
    """
    mask = can_attend(bits, pos, bits, pos)
    return jnp.sum(mask.astype(jnp.int32), axis=1)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  bits_q: jax.Array, pos_q: jax.Array,
                  bits_k: jax.Array, pos_k: jax.Array) -> jax.Array:
    """Reference BAM attention.

    Args:
      q: f32[Tq, H, D] queries.
      k: f32[Tk, H, D] keys.
      v: f32[Tk, H, D] values.
      bits_*/pos_*: bitfield/position vectors as in :func:`can_attend`.

    Returns:
      f32[Tq, H, D] attention output. Rows are never fully masked because
      every token can attend itself under both rules.
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    # [H, Tq, Tk]
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
    mask = can_attend(bits_q, pos_q, bits_k, pos_k)  # [Tq, Tk]
    scores = jnp.where(mask[None, :, :], scores, jnp.asarray(-1e30, q.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", probs, v)


def attention_ref_vjp(q, k, v, bits_q, pos_q, bits_k, pos_k, g):
    """Gradients of :func:`attention_ref` w.r.t. (q, k, v).

    Used as the backward rule of the Pallas kernel's ``jax.custom_vjp``:
    the forward hot path runs the blockwise kernel, the backward runs
    these XLA ops (recomputing scores — gradient checkpointing style). On
    a real TPU this would be a second Pallas kernel; the interchange
    contract (same HLO artifact, no residual shipping) is identical.
    """
    def f(q, k, v):
        return attention_ref(q, k, v, bits_q, pos_q, bits_k, pos_k)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


# ---------------------------------------------------------------------------
# Convenience mask builders mirrored in rust (bam::generators). These are
# used by tests only; the rust side is the one used by benches.
# ---------------------------------------------------------------------------

def make_bits_ep(text_len: int, seg_lens: list[int]) -> tuple[jax.Array, jax.Array]:
    """'Encoder outputs Prepended' layout: [mod_1 .. mod_k, text]."""
    bits = []
    for m, L in enumerate(seg_lens):
        bits += [1 << (m + 1)] * L
    text_bits = TEXT_BIT
    for m in range(len(seg_lens)):
        text_bits |= 1 << (m + 1)
    bits += [text_bits] * text_len
    b = jnp.asarray(bits, dtype=jnp.int32)
    return b, jnp.arange(b.shape[0], dtype=jnp.int32)


def make_bits_ee(text_lens: list[int], seg_lens: list[int]) -> tuple[jax.Array, jax.Array]:
    """'Encoder outputs Embedded': text_0, mod_1, text_1, mod_2, ..., text_k.

    ``len(text_lens) == len(seg_lens) + 1``. Text tokens attend every
    modality segment (all bits set), matching the paper's Figure 11b.
    """
    assert len(text_lens) == len(seg_lens) + 1
    text_bits = TEXT_BIT
    for m in range(len(seg_lens)):
        text_bits |= 1 << (m + 1)
    bits = [text_bits] * text_lens[0]
    for m, L in enumerate(seg_lens):
        bits += [1 << (m + 1)] * L
        bits += [text_bits] * text_lens[m + 1]
    b = jnp.asarray(bits, dtype=jnp.int32)
    return b, jnp.arange(b.shape[0], dtype=jnp.int32)
