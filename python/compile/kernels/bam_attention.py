"""L1: Pallas blockwise BAM (Bitfield Attention Mask) attention kernel.

The paper's context-parallel attention rides PyTorch FlexAttention (CUDA
block-sparse masking in SRAM). TPU rethink (DESIGN.md §Hardware-Adaptation):

* Q is tiled into ``BLK_Q``-row blocks (one grid step per (head, q-block)),
  K/V stream through VMEM in ``BLK_K``-column tiles inside an on-chip loop
  — BlockSpec expresses the HBM↔VMEM schedule the paper expressed with
  threadblocks.
* The BAM predicate is evaluated per (BLK_Q, BLK_K) tile from two tiny 1-D
  int32 vectors (bits, pos) that stay resident in VMEM; the [T,T] mask is
  **never** materialized, which is the entire point of BAM (§4.3.1).
* Online softmax (flash-style): running row-max ``m`` and row-sum ``l``
  carried across K tiles; the MXU sees plain (BLK_Q, D) x (D, BLK_K)
  matmuls in f32 (bf16 on real TPU).
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; interpret mode lowers the kernel to plain HLO so the same
  artifact runs under the rust runtime. Block sizes are still chosen for
  the TPU VMEM budget (see ``vmem_bytes``).

Autodiff: ``pallas_call`` has no VJP rule; ``bam_attention`` is wrapped in
``jax.custom_vjp`` whose backward recomputes scores with pure-jnp ops
(gradient checkpointing style — no residual softmax stats are shipped).
On a real TPU deployment the backward would be a second Pallas kernel; the
artifact interface is unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLK_Q = 128
DEFAULT_BLK_K = 128
NEG_INF = -1e30


def _bam_fwd_kernel(bits_q_ref, pos_q_ref, bits_k_ref, pos_k_ref,
                    q_ref, k_ref, v_ref, o_ref, *, blk_k: int, tk: int,
                    scale: float):
    """One (head, q-block) grid step.

    Refs (per BlockSpec):
      bits_q_ref/pos_q_ref: i32[BLK_Q]   — bitfields/positions of this q tile
      bits_k_ref/pos_k_ref: i32[Tk]      — full key metadata (tiny, stays in VMEM)
      q_ref: f32[BLK_Q, D]
      k_ref: f32[Tk, D]   — full K for this head (VMEM-resident at these sizes;
                            a production TPU kernel double-buffers HBM tiles)
      v_ref: f32[Tk, D]
      o_ref: f32[BLK_Q, D]
    """
    blk_q, d = q_ref.shape
    q = q_ref[...] * scale
    bq = bits_q_ref[...]
    pq = pos_q_ref[...]

    is_text = (bq & ref.TEXT_BIT) != 0  # [BLK_Q]

    def body(i, carry):
        acc, m_i, l_i = carry
        start = i * blk_k
        k_tile = jax.lax.dynamic_slice(k_ref[...], (start, 0), (blk_k, d))
        v_tile = jax.lax.dynamic_slice(v_ref[...], (start, 0), (blk_k, d))
        bk = jax.lax.dynamic_slice(bits_k_ref[...], (start,), (blk_k,))
        pk = jax.lax.dynamic_slice(pos_k_ref[...], (start,), (blk_k,))

        s = q @ k_tile.T  # [BLK_Q, BLK_K] — the MXU tile

        # BAM predicate, evaluated on the integer metadata tiles only.
        text_rule = (pk[None, :] <= pq[:, None]) & ((bq[:, None] & bk[None, :]) != 0)
        mod_rule = bk[None, :] == bq[:, None]
        mask = jnp.where(is_text[:, None], text_rule, mod_rule)

        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        # exp of masked-out lanes is exp(NEG_INF - m) == 0: no NaN leakage.
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_new, l_new

    acc0 = jnp.zeros((blk_q, d), dtype=jnp.float32)
    m0 = jnp.full((blk_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((blk_q,), dtype=jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, tk // blk_k, body, (acc0, m0, l0))
    # Every token attends at least itself, so l_i > 0 whenever the q tile is
    # real; padded tail rows (pos == -1, bits == 0) divide by max(l, 1).
    o_ref[...] = acc / jnp.maximum(l_i, 1e-30)[:, None]


def _pad_to(x, mult, axis, fill):
    t = x.shape[axis]
    rem = (-t) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=fill)


def bam_attention_fwd_kernel(q, k, v, bits_q, pos_q, bits_k, pos_k,
                             blk_q: int = DEFAULT_BLK_Q,
                             blk_k: int = DEFAULT_BLK_K):
    """Blockwise BAM attention forward via Pallas.

    Args:
      q: f32[Tq, H, D]; k, v: f32[Tk, H, D]; bits/pos as in ref.can_attend.

    Returns f32[Tq, H, D].
    """
    tq, h, d = q.shape
    tk = k.shape[0]
    blk_q = min(blk_q, max(8, tq))
    blk_k = min(blk_k, max(8, tk))
    scale = 1.0 / float(d) ** 0.5

    # Pad so the grid divides evenly. Padded q rows have bits=0/pos=-1 (they
    # produce garbage rows that are sliced off); padded k columns have
    # bits=0/pos=2^30 so no real token ever attends them (text rule fails on
    # bits&0==0, modality rule fails on bits!=0 segments).
    qp = _pad_to(q, blk_q, 0, 0.0)
    bqp = _pad_to(bits_q, blk_q, 0, 0)
    pqp = _pad_to(pos_q, blk_q, 0, -1)
    kp = _pad_to(k, blk_k, 0, 0.0)
    vp = _pad_to(v, blk_k, 0, 0.0)
    bkp = _pad_to(bits_k, blk_k, 0, 0)
    pkp = _pad_to(pos_k, blk_k, 0, 1 << 30)
    tqp, tkp = qp.shape[0], kp.shape[0]

    # [T, H, D] -> [H, T, D] so each grid step sees one head's tile.
    qh = jnp.transpose(qp, (1, 0, 2))
    kh = jnp.transpose(kp, (1, 0, 2))
    vh = jnp.transpose(vp, (1, 0, 2))

    grid = (h, tqp // blk_q)
    kernel = functools.partial(_bam_fwd_kernel, blk_k=blk_k, tk=tkp,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q,), lambda hh, iq: (iq,)),     # bits_q tile
            pl.BlockSpec((blk_q,), lambda hh, iq: (iq,)),     # pos_q tile
            pl.BlockSpec((tkp,), lambda hh, iq: (0,)),        # bits_k (full)
            pl.BlockSpec((tkp,), lambda hh, iq: (0,)),        # pos_k (full)
            pl.BlockSpec((None, blk_q, d), lambda hh, iq: (hh, iq, 0)),
            pl.BlockSpec((None, tkp, d), lambda hh, iq: (hh, 0, 0)),
            pl.BlockSpec((None, tkp, d), lambda hh, iq: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, blk_q, d), lambda hh, iq: (hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tqp, d), jnp.float32),
        interpret=True,
    )(bqp, pqp, bkp, pkp,
      qh.reshape(h, tqp // blk_q * blk_q, d),
      kh, vh)
    out = jnp.transpose(out, (1, 0, 2))[:tq]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8))
def bam_attention(q, k, v, bits_q, pos_q, bits_k, pos_k,
                  blk_q: int = DEFAULT_BLK_Q, blk_k: int = DEFAULT_BLK_K):
    """Differentiable BAM attention: Pallas fwd, recompute-jnp bwd."""
    return bam_attention_fwd_kernel(q, k, v, bits_q, pos_q, bits_k, pos_k,
                                    blk_q, blk_k)


def _fwd(q, k, v, bits_q, pos_q, bits_k, pos_k, blk_q, blk_k):
    out = bam_attention_fwd_kernel(q, k, v, bits_q, pos_q, bits_k, pos_k,
                                   blk_q, blk_k)
    return out, (q, k, v, bits_q, pos_q, bits_k, pos_k)


def _bwd(blk_q, blk_k, res, g):
    q, k, v, bits_q, pos_q, bits_k, pos_k = res
    dq, dk, dv = ref.attention_ref_vjp(q, k, v, bits_q, pos_q, bits_k,
                                       pos_k, g)
    zero_bits = jnp.zeros_like(bits_q), jnp.zeros_like(pos_q), \
        jnp.zeros_like(bits_k), jnp.zeros_like(pos_k)
    return (dq, dk, dv) + zero_bits


bam_attention.defvjp(_fwd, _bwd)


def vmem_bytes(blk_q: int, blk_k: int, d: int, tk: int) -> int:
    """Estimated VMEM working set of one grid step, used by the perf pass
    (DESIGN.md §Perf) to keep tiles inside a 16 MB TPU VMEM budget."""
    f32 = 4
    q_tile = blk_q * d * f32
    kv = 2 * tk * d * f32
    acc = blk_q * d * f32
    stats = 2 * blk_q * f32
    meta = 2 * (blk_q + tk) * 4
    score = blk_q * blk_k * f32
    return q_tile + kv + acc + stats + meta + score
