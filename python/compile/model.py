"""L2: the modular MLLM compute graph (JAX, build-time only).

This is the JAX half of the paper's programming model: an MLLM is a DAG of
*components* — modality encoders, projectors, and an LLM split into pipeline
stages — mirroring Cornstarch's ``ModalityModule`` / ``MultimodalModule``
(§3.2). The rust L3 coordinator owns the graph, schedule, and parallelism;
this module only defines the per-component math and exports it per stage.

Artifact contract (what `aot.py` lowers, what rust loads):

Every component ``c`` with forward ``f_c(flat_params, *inputs) -> out``
exports up to four HLO programs:

* ``fwd``    : ``(flat, *ins) -> out``
* ``bwd``    : ``(flat, *ins, g) -> (dflat, dins...)``   (trainable path,
  recomputes activations inside — gradient checkpointing, §4.2)
* ``bwdin``  : ``(flat, *ins, g) -> (dins...)``          (frozen-but-must-
  propagate path: the paper's ``T_bwd = 1×T_fwd`` case as a literal program)
* ``upd``    : ``(flat, g, m, v, step, lr) -> (flat', m', v')``  (AdamW)

Parameters travel as ONE flat f32 vector per component (stable layout
recorded in the manifest), so the rust side holds exactly one resident
device buffer per component for params and one per optimizer slot, and the
``0 / 1x / 2x`` frozen rule of §4.2 becomes a choice between artifacts
rather than a modeling assumption.

Token layout is the paper's "encoder outputs embedded" (EE) style: modality
segments are spliced into the text stream at a fixed position; the BAM bits
vector for the layout is reconstructed by rust from manifest ``segment``
records and fed to the attention kernel at run time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.bam_attention import bam_attention

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """A modality encoder (ViT/Whisper-like transformer over pre-patchified
    features). ``d_input`` is the per-token raw feature width (e.g. flattened
    image patch or audio frame stack)."""
    name: str
    d_input: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    n_tokens: int  # tokens this encoder contributes to the LLM sequence


@dataclasses.dataclass(frozen=True)
class LlmConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int


@dataclasses.dataclass(frozen=True)
class MllmConfig:
    """A full MLLM: encoders + projectors + pipeline-staged LLM."""
    name: str
    llm: LlmConfig
    encoders: tuple[EncoderConfig, ...]
    text_len: int
    insert_at: int            # modality segments spliced before this text pos
    llm_stage_layers: tuple[int, ...]  # layers per LLM pipeline stage

    @property
    def total_tokens(self) -> int:
        return self.text_len + sum(e.n_tokens for e in self.encoders)

    def segments(self) -> list[tuple[str, int, int, int]]:
        """(kind, start, end, bit) records; mirrored by rust bam::generators."""
        segs = []
        text_bits = ref.TEXT_BIT
        for m, _ in enumerate(self.encoders):
            text_bits |= 1 << (m + 1)
        cur = 0
        if self.insert_at > 0:
            segs.append(("text", 0, self.insert_at, text_bits))
            cur = self.insert_at
        for m, e in enumerate(self.encoders):
            segs.append((e.name, cur, cur + e.n_tokens, 1 << (m + 1)))
            cur += e.n_tokens
        segs.append(("text", cur, cur + self.text_len - self.insert_at, text_bits))
        return segs

    def bits_pos(self) -> tuple[jax.Array, jax.Array]:
        bits = np.zeros(self.total_tokens, dtype=np.int32)
        for _, s, e, b in self.segments():
            bits[s:e] = b
        pos = np.arange(self.total_tokens, dtype=np.int32)
        return jnp.asarray(bits), jnp.asarray(pos)


# Registry of model configs used by tests / examples / e2e.
# "tiny"  : sub-1M params, used by pytest and rust integration tests.
# "mini"  : ~35M params, quickstart example.
# "e2e100m": ~100M-class params, the mandated end-to-end training driver.
CONFIGS: dict[str, MllmConfig] = {
    "tiny": MllmConfig(
        name="tiny",
        llm=LlmConfig(vocab=512, d_model=64, n_layers=4, n_heads=4, d_ff=128),
        encoders=(EncoderConfig("vision", d_input=48, d_model=48, n_layers=2,
                                n_heads=4, d_ff=96, n_tokens=8),),
        text_len=24, insert_at=4, llm_stage_layers=(2, 2),
    ),
    "tiny_va": MllmConfig(
        name="tiny_va",
        llm=LlmConfig(vocab=512, d_model=64, n_layers=4, n_heads=4, d_ff=128),
        encoders=(
            EncoderConfig("vision", d_input=48, d_model=48, n_layers=2,
                          n_heads=4, d_ff=96, n_tokens=8),
            EncoderConfig("audio", d_input=32, d_model=40, n_layers=2,
                          n_heads=4, d_ff=80, n_tokens=6),
        ),
        text_len=24, insert_at=4, llm_stage_layers=(2, 2),
    ),
    "mini": MllmConfig(
        name="mini",
        llm=LlmConfig(vocab=8192, d_model=512, n_layers=8, n_heads=8,
                      d_ff=2048),
        encoders=(EncoderConfig("vision", d_input=192, d_model=256,
                                n_layers=4, n_heads=4, d_ff=1024,
                                n_tokens=16),),
        text_len=96, insert_at=8, llm_stage_layers=(4, 4),
    ),
    "e2e100m": MllmConfig(
        name="e2e100m",
        llm=LlmConfig(vocab=16384, d_model=768, n_layers=12, n_heads=12,
                      d_ff=3072),
        encoders=(EncoderConfig("vision", d_input=192, d_model=384,
                                n_layers=4, n_heads=6, d_ff=1536,
                                n_tokens=16),),
        text_len=112, insert_at=8, llm_stage_layers=(6, 6),
    ),
}


# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------


class Layout:
    """Deterministic name->(offset, shape) layout of a parameter tree.

    The manifest records it so rust (and tests) can slice individual
    parameters out of the flat vector for inspection / checkpointing.
    """

    def __init__(self):
        self.entries: list[tuple[str, int, tuple[int, ...]]] = []
        self.total = 0

    def add(self, name: str, shape: tuple[int, ...]) -> None:
        n = int(np.prod(shape)) if shape else 1
        self.entries.append((name, self.total, shape))
        self.total += n

    def slice(self, flat: jax.Array, name: str) -> jax.Array:
        for n, off, shape in self.entries:
            if n == name:
                size = int(np.prod(shape)) if shape else 1
                return jax.lax.dynamic_slice(flat, (off,), (size,)).reshape(shape)
        raise KeyError(name)


def _transformer_layout(prefix: str, d: int, n_layers: int, d_ff: int,
                        layout: Layout, layers: range | None = None) -> None:
    rng = layers if layers is not None else range(n_layers)
    for i in rng:
        p = f"{prefix}.blocks.{i}"
        layout.add(f"{p}.ln1.scale", (d,))
        layout.add(f"{p}.ln1.bias", (d,))
        layout.add(f"{p}.attn.wq", (d, d))
        layout.add(f"{p}.attn.wk", (d, d))
        layout.add(f"{p}.attn.wv", (d, d))
        layout.add(f"{p}.attn.wo", (d, d))
        layout.add(f"{p}.ln2.scale", (d,))
        layout.add(f"{p}.ln2.bias", (d,))
        layout.add(f"{p}.mlp.w1", (d, d_ff))
        layout.add(f"{p}.mlp.w2", (d_ff, d))


def encoder_layout(e: EncoderConfig) -> Layout:
    lo = Layout()
    lo.add("in_proj.w", (e.d_input, e.d_model))
    lo.add("in_proj.b", (e.d_model,))
    lo.add("pos_embed", (e.n_tokens, e.d_model))
    _transformer_layout("enc", e.d_model, e.n_layers, e.d_ff, lo)
    lo.add("ln_f.scale", (e.d_model,))
    lo.add("ln_f.bias", (e.d_model,))
    return lo


def projector_layout(e: EncoderConfig, llm: LlmConfig) -> Layout:
    lo = Layout()
    lo.add("w", (e.d_model, llm.d_model))
    lo.add("b", (llm.d_model,))
    return lo


def llm_stage_layout(cfg: MllmConfig, stage: int) -> Layout:
    """LLM stage `stage`: first stage owns embed (+pos), last owns ln_f+head."""
    llm = cfg.llm
    lo = Layout()
    lo_layers = _stage_layer_range(cfg, stage)
    if stage == 0:
        lo.add("embed", (llm.vocab, llm.d_model))
        lo.add("pos_embed", (cfg.total_tokens, llm.d_model))
    _transformer_layout("llm", llm.d_model, llm.n_layers, llm.d_ff, lo,
                        layers=lo_layers)
    if stage == len(cfg.llm_stage_layers) - 1:
        lo.add("ln_f.scale", (llm.d_model,))
        lo.add("ln_f.bias", (llm.d_model,))
        lo.add("head", (llm.d_model, llm.vocab))
    return lo


def _stage_layer_range(cfg: MllmConfig, stage: int) -> range:
    start = sum(cfg.llm_stage_layers[:stage])
    return range(start, start + cfg.llm_stage_layers[stage])


# ---------------------------------------------------------------------------
# Core math
# ---------------------------------------------------------------------------


def _layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * scale + bias


def _full_attention(x: jax.Array, wq, wk, wv, wo, n_heads: int) -> jax.Array:
    """Bidirectional full attention (encoder blocks)."""
    t, d = x.shape
    dh = d // n_heads
    q = (x @ wq).reshape(t, n_heads, dh)
    k = (x @ wk).reshape(t, n_heads, dh)
    v = (x @ wv).reshape(t, n_heads, dh)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("qhd,khd->hqk", q, k) * scale
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hqk,khd->qhd", p, v).reshape(t, d)
    return o @ wo


def _bam_block_attention(x, wq, wk, wv, wo, n_heads, bits, pos):
    """LLM self-attention through the L1 Pallas BAM kernel."""
    t, d = x.shape
    dh = d // n_heads
    q = (x @ wq).reshape(t, n_heads, dh)
    k = (x @ wk).reshape(t, n_heads, dh)
    v = (x @ wv).reshape(t, n_heads, dh)
    o = bam_attention(q, k, v, bits, pos, bits, pos)
    return o.reshape(t, d) @ wo


def _block(x, lo: Layout, flat, prefix: str, n_heads: int,
           attn: Callable) -> jax.Array:
    h = _layer_norm(x, lo.slice(flat, f"{prefix}.ln1.scale"),
                    lo.slice(flat, f"{prefix}.ln1.bias"))
    x = x + attn(h,
                 lo.slice(flat, f"{prefix}.attn.wq"),
                 lo.slice(flat, f"{prefix}.attn.wk"),
                 lo.slice(flat, f"{prefix}.attn.wv"),
                 lo.slice(flat, f"{prefix}.attn.wo"),
                 n_heads)
    h = _layer_norm(x, lo.slice(flat, f"{prefix}.ln2.scale"),
                    lo.slice(flat, f"{prefix}.ln2.bias"))
    x = x + jax.nn.gelu(h @ lo.slice(flat, f"{prefix}.mlp.w1")) @ \
        lo.slice(flat, f"{prefix}.mlp.w2")
    return x


# ---------------------------------------------------------------------------
# Component forwards (flat-param signatures, exported per stage)
# ---------------------------------------------------------------------------


def encoder_fwd(e: EncoderConfig) -> Callable:
    lo = encoder_layout(e)

    def f(flat: jax.Array, x: jax.Array) -> jax.Array:
        """x: f32[n_tokens, d_input] pre-patchified modality features."""
        h = x @ lo.slice(flat, "in_proj.w") + lo.slice(flat, "in_proj.b")
        h = h + lo.slice(flat, "pos_embed")
        for i in range(e.n_layers):
            h = _block(h, lo, flat, f"enc.blocks.{i}", e.n_heads,
                       _full_attention)
        return _layer_norm(h, lo.slice(flat, "ln_f.scale"),
                           lo.slice(flat, "ln_f.bias"))

    return f


def projector_fwd(e: EncoderConfig, llm: LlmConfig) -> Callable:
    lo = projector_layout(e, llm)

    def f(flat: jax.Array, feats: jax.Array) -> jax.Array:
        return feats @ lo.slice(flat, "w") + lo.slice(flat, "b")

    return f


def llm_stage_fwd(cfg: MllmConfig, stage: int) -> Callable:
    """First stage: (flat, text_ids, *mod_h, bits, pos) -> h.
    Middle stages: (flat, h, bits, pos) -> h.
    Last stage also computes ln_f (head/loss live in llm_head_fwd)."""
    lo = llm_stage_layout(cfg, stage)
    llm = cfg.llm
    layers = _stage_layer_range(cfg, stage)
    is_first = stage == 0
    is_last = stage == len(cfg.llm_stage_layers) - 1

    def run_layers(flat, h, bits, pos):
        for i in layers:
            h = _block(
                h, lo, flat, f"llm.blocks.{i}", llm.n_heads,
                lambda x, wq, wk, wv, wo, nh: _bam_block_attention(
                    x, wq, wk, wv, wo, nh, bits, pos))
        if is_last:
            h = _layer_norm(h, lo.slice(flat, "ln_f.scale"),
                            lo.slice(flat, "ln_f.bias"))
        return h

    if is_first:
        def f(flat, text_ids, *rest):
            mod_hs = rest[:len(cfg.encoders)]
            bits, pos = rest[len(cfg.encoders):]
            embed = lo.slice(flat, "embed")
            text_emb = embed[text_ids]  # [text_len, d]
            pieces = [text_emb[:cfg.insert_at]]
            pieces.extend(mod_hs)
            pieces.append(text_emb[cfg.insert_at:])
            h = jnp.concatenate(pieces, axis=0)
            h = h + lo.slice(flat, "pos_embed")
            return run_layers(flat, h, bits, pos)
        return f

    def f(flat, h, bits, pos):
        return run_layers(flat, h, bits, pos)

    return f


def llm_head_fwd(cfg: MllmConfig) -> Callable:
    """Loss head: (flat_of_last_stage, h, labels) -> mean CE over labels>=0.

    Shares the last LLM stage's flat vector (the head weights live there);
    exported as its own artifact so the coordinator can place loss
    computation at the pipeline tail, as in the paper's execution graph.
    """
    lo = llm_stage_layout(cfg, len(cfg.llm_stage_layers) - 1)

    def f(flat, h, labels):
        logits = h @ lo.slice(flat, "head")  # [T, vocab]
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = labels >= 0
        safe = jnp.where(valid, labels, 0)
        tok_ll = jnp.take_along_axis(logp, safe[:, None], axis=1)[:, 0]
        n = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
        return -jnp.sum(jnp.where(valid, tok_ll, 0.0)) / n

    return f


# ---------------------------------------------------------------------------
# Whole-model reference (for tests and the loss oracle)
# ---------------------------------------------------------------------------


def mllm_forward(cfg: MllmConfig, flats: dict[str, jax.Array],
                 text_ids: jax.Array, mod_inputs: dict[str, jax.Array],
                 labels: jax.Array) -> jax.Array:
    """End-to-end loss computed by chaining the exact stage functions that
    get exported — the oracle for the rust executor's numerics."""
    bits, pos = cfg.bits_pos()
    mod_hs = []
    for e in cfg.encoders:
        feats = encoder_fwd(e)(flats[f"enc:{e.name}"], mod_inputs[e.name])
        mod_hs.append(projector_fwd(e, cfg.llm)(flats[f"proj:{e.name}"], feats))
    h = llm_stage_fwd(cfg, 0)(flats["llm:0"], text_ids, *mod_hs, bits, pos)
    for s in range(1, len(cfg.llm_stage_layers)):
        h = llm_stage_fwd(cfg, s)(flats[f"llm:{s}"], h, bits, pos)
    return llm_head_fwd(cfg)(flats[f"llm:{len(cfg.llm_stage_layers)-1}"],
                             h, labels)


# ---------------------------------------------------------------------------
# Components registry: name -> (layout, fwd, input_specs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Component:
    """One exported pipeline component."""
    name: str
    kind: str  # encoder | projector | llm_stage | llm_head
    layout: Layout
    fwd: Callable
    # (name, dtype, shape, differentiable) per non-param input
    inputs: list[tuple[str, str, tuple[int, ...], bool]]
    out_shape: tuple[int, ...]
    shares_params_with: str | None = None  # llm_head shares the last stage


def components(cfg: MllmConfig) -> list[Component]:
    comps: list[Component] = []
    t = cfg.total_tokens
    d = cfg.llm.d_model
    for e in cfg.encoders:
        comps.append(Component(
            name=f"enc:{e.name}", kind="encoder", layout=encoder_layout(e),
            fwd=encoder_fwd(e),
            inputs=[("x", "f32", (e.n_tokens, e.d_input), True)],
            out_shape=(e.n_tokens, e.d_model)))
        comps.append(Component(
            name=f"proj:{e.name}", kind="projector",
            layout=projector_layout(e, cfg.llm),
            fwd=projector_fwd(e, cfg.llm),
            inputs=[("feats", "f32", (e.n_tokens, e.d_model), True)],
            out_shape=(e.n_tokens, d)))
    n_stages = len(cfg.llm_stage_layers)
    for s in range(n_stages):
        if s == 0:
            ins = [("text_ids", "i32", (cfg.text_len,), False)]
            ins += [(f"mod_h_{e.name}", "f32", (e.n_tokens, d), True)
                    for e in cfg.encoders]
            ins += [("bits", "i32", (t,), False), ("pos", "i32", (t,), False)]
        else:
            ins = [("h", "f32", (t, d), True),
                   ("bits", "i32", (t,), False), ("pos", "i32", (t,), False)]
        comps.append(Component(
            name=f"llm:{s}", kind="llm_stage",
            layout=llm_stage_layout(cfg, s), fwd=llm_stage_fwd(cfg, s),
            inputs=ins, out_shape=(t, d)))
    comps.append(Component(
        name="llm:head", kind="llm_head",
        layout=llm_stage_layout(cfg, n_stages - 1), fwd=llm_head_fwd(cfg),
        inputs=[("h", "f32", (t, d), True), ("labels", "i32", (t,), False)],
        out_shape=(), shares_params_with=f"llm:{n_stages-1}"))
    return comps


# ---------------------------------------------------------------------------
# Init + AdamW
# ---------------------------------------------------------------------------


def init_flat(layout: Layout, seed: int) -> np.ndarray:
    """Deterministic init: truncated-normal-ish scaled by fan-in for
    matrices, ones for ln scales, zeros for biases."""
    rng = np.random.default_rng(seed)
    flat = np.zeros(layout.total, dtype=np.float32)
    for name, off, shape in layout.entries:
        n = int(np.prod(shape)) if shape else 1
        if name.endswith(".scale"):
            flat[off:off + n] = 1.0
        elif name.endswith(".bias") or name.endswith(".b"):
            pass  # zeros
        elif len(shape) >= 2:
            std = 1.0 / math.sqrt(shape[0])
            flat[off:off + n] = rng.normal(0.0, std, size=n).astype(np.float32)
        else:
            flat[off:off + n] = rng.normal(0.0, 0.02, size=n).astype(np.float32)
    return flat


def adamw_update(flat, grad, m, v, step, lr,
                 beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.01):
    """One AdamW step over a flat parameter vector (exported as ``upd``)."""
    m = beta1 * m + (1 - beta1) * grad
    v = beta2 * v + (1 - beta2) * grad * grad
    mhat = m / (1 - beta1 ** step)
    vhat = v / (1 - beta2 ** step)
    new = flat - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * flat)
    return new, m, v


# ---------------------------------------------------------------------------
# bwd wrappers used by aot.py
# ---------------------------------------------------------------------------


def make_bwd(comp: Component, with_params: bool) -> Callable:
    """Build the backward program for a component.

    ``with_params=True``  -> ``bwd``   (dflat, d(diff inputs)...)
    ``with_params=False`` -> ``bwdin`` (d(diff inputs)...)

    The forward is recomputed inside (gradient checkpointing): only
    (flat, inputs, g) cross the wire, never residuals.
    """
    diff_idx = [i for i, (_, _, _, dble) in enumerate(comp.inputs) if dble]
    is_head = comp.kind == "llm_head"

    def bwd(flat, *args):
        # head: loss is the scalar root, so no incoming cotangent g.
        ins, g = (args, None) if is_head else (args[:-1], args[-1])

        def f(flat, *diff_ins):
            full = list(ins)
            for j, i in enumerate(diff_idx):
                full[i] = diff_ins[j]
            return comp.fwd(flat, *full)

        diff_ins = tuple(ins[i] for i in diff_idx)
        if is_head:
            argnums = tuple(range(0 if with_params else 1, 1 + len(diff_idx)))
            return jax.grad(f, argnums=argnums)(flat, *diff_ins)
        _, vjp = jax.vjp(f, flat, *diff_ins)
        grads = vjp(g)
        return grads if with_params else grads[1:]

    return bwd
