"""AOT export: lower every per-stage program to HLO *text* + manifest.

Python runs ONCE here (``make artifacts``); the rust coordinator is
self-contained afterwards. HLO text — not ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Per model config this writes, under ``artifacts/<model>/``:

* ``<comp>.<role>.hlo.txt`` for role in {fwd, bwd, bwdin, upd} (head has no
  upd — it shares the last LLM stage's parameters),
* ``params/<comp>.f32.bin`` — deterministic flat f32 init (little-endian),
* ``manifest.txt`` — line-based description (models, components, artifact
  I/O specs, segment/BAM layout, graph edges) parsed by
  ``rust/src/runtime/manifest.rs``.

Also exports standalone BAM-attention artifacts (``attn<T>``) used by the
context-parallelism benches to cross-check the workload model with real
PJRT execution.

Manifest grammar (one record per line, ``#`` comments):

    model <name>
    tokens <total> text <text_len> insert <insert_at> vocab <vocab>
    segment <name> <start> <end> <bits>
    component <name> <kind> <n_params> shares=<other|->
    params <comp> <relpath> <n_elems>
    artifact <comp> <role> <relpath> ins=<n:d:s,...;...> outs=<...>
    edge <from> <to>
    attn <name> <relpath> <T> <H> <D>

where an I/O spec is ``name:dtype:dims`` with dims ``AxBxC`` (scalar = "_").
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.bam_attention import bam_attention_fwd_kernel


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(name: str, aval) -> str:
    dt = {"float32": "f32", "int32": "i32"}[str(aval.dtype)]
    dims = "x".join(str(d) for d in aval.shape) if aval.shape else "_"
    return f"{name}:{dt}:{dims}"


def _abstract(dtype: str, shape: tuple[int, ...]):
    jdt = {"f32": jnp.float32, "i32": jnp.int32}[dtype]
    return jax.ShapeDtypeStruct(shape, jdt)


def lower_and_write(fn, example_args, names, out_path: str) -> list[str]:
    """Lower fn at the example avals, write HLO text, return the in-specs."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return [_spec_str(n, a) for n, a in zip(names, example_args)]


def _out_specs(fn, example_args) -> list[str]:
    outs = jax.eval_shape(fn, *example_args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return [_spec_str(f"o{i}", a) for i, a in enumerate(outs)]


def export_model(cfg: M.MllmConfig, out_root: str, manifest: list[str]) -> None:
    mdir = os.path.join(out_root, cfg.name)
    os.makedirs(os.path.join(mdir, "params"), exist_ok=True)
    t0 = time.time()

    manifest.append(f"model {cfg.name}")
    manifest.append(
        f"tokens {cfg.total_tokens} text {cfg.text_len} "
        f"insert {cfg.insert_at} vocab {cfg.llm.vocab}")
    for name, s, e, b in cfg.segments():
        manifest.append(f"segment {name} {s} {e} {b}")

    comps = M.components(cfg)
    for comp in comps:
        lo = comp.layout
        shares = comp.shares_params_with or "-"
        manifest.append(
            f"component {comp.name} {comp.kind} {lo.total} shares={shares}")

        safe = comp.name.replace(":", "_")
        # ---- params init (only for components that own their params)
        if comp.shares_params_with is None:
            flat = M.init_flat(lo, seed=hash(comp.name) % (2**31))
            rel = f"params/{safe}.f32.bin"
            flat.tofile(os.path.join(mdir, rel))
            manifest.append(f"params {comp.name} {cfg.name}/{rel} {lo.total}")

        flat_aval = _abstract("f32", (lo.total,))
        in_avals = [_abstract(dt, sh) for (_, dt, sh, _) in comp.inputs]
        in_names = [n for (n, _, _, _) in comp.inputs]
        g_aval = _abstract("f32", comp.out_shape)

        def emit(role: str, fn, args, names):
            rel = f"{cfg.name}/{safe}.{role}.hlo.txt"
            ins = lower_and_write(fn, args, names,
                                  os.path.join(out_root, rel))
            outs = _out_specs(fn, args)
            manifest.append(
                f"artifact {comp.name} {role} {rel} "
                f"ins={';'.join(ins)} outs={';'.join(outs)}")

        # ---- fwd
        emit("fwd", comp.fwd, [flat_aval, *in_avals], ["flat", *in_names])

        # ---- bwd / bwdin
        bwd_full = M.make_bwd(comp, with_params=True)
        bwd_in = M.make_bwd(comp, with_params=False)
        if comp.kind == "llm_head":
            bwd_args = [flat_aval, *in_avals]
            bwd_names = ["flat", *in_names]
        else:
            bwd_args = [flat_aval, *in_avals, g_aval]
            bwd_names = ["flat", *in_names, "g"]
        emit("bwd", bwd_full, bwd_args, bwd_names)
        emit("bwdin", bwd_in, bwd_args, bwd_names)

        # ---- optimizer update
        if comp.shares_params_with is None:
            p = _abstract("f32", (lo.total,))
            s = _abstract("f32", ())
            emit("upd", M.adamw_update, [p, p, p, p, s, s],
                 ["flat", "grad", "m", "v", "step", "lr"])

    for e in cfg.encoders:
        manifest.append(f"edge enc:{e.name} proj:{e.name}")
        manifest.append(f"edge proj:{e.name} llm:0")
    for s in range(1, len(cfg.llm_stage_layers)):
        manifest.append(f"edge llm:{s-1} llm:{s}")
    manifest.append(f"edge llm:{len(cfg.llm_stage_layers)-1} llm:head")
    print(f"  exported {cfg.name} in {time.time()-t0:.1f}s")


def export_attn(out_root: str, manifest: list[str],
                sizes=((128, 4, 32), (512, 8, 64))) -> None:
    """Standalone BAM attention artifacts for the CP benches/tests."""
    adir = os.path.join(out_root, "attn")
    os.makedirs(adir, exist_ok=True)
    for t, h, d in sizes:
        name = f"attn{t}"
        rel = f"attn/{name}.fwd.hlo.txt"

        def fn(q, k, v, bits_q, pos_q, bits_k, pos_k):
            return bam_attention_fwd_kernel(q, k, v, bits_q, pos_q,
                                            bits_k, pos_k)

        qa = _abstract("f32", (t, h, d))
        ia = _abstract("i32", (t,))
        ins = lower_and_write(
            fn, [qa, qa, qa, ia, ia, ia, ia],
            ["q", "k", "v", "bits_q", "pos_q", "bits_k", "pos_k"],
            os.path.join(out_root, rel))
        manifest.append(f"attn {name} {rel} {t} {h} {d}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts root directory")
    ap.add_argument("--models", default="tiny,tiny_va,mini",
                    help="comma list of configs (or 'all'); e2e100m is "
                         "exported by examples/train_vlm via ARTIFACT_MODELS")
    args = ap.parse_args()

    models = list(M.CONFIGS) if args.models == "all" else \
        [m for m in args.models.split(",") if m]
    env = os.environ.get("ARTIFACT_MODELS")
    if env:
        models = sorted(set(models) | {m for m in env.split(",") if m})

    out_root = args.out
    os.makedirs(out_root, exist_ok=True)
    manifest: list[str] = ["# generated by python/compile/aot.py"]
    for name in models:
        export_model(M.CONFIGS[name], out_root, manifest)
    export_attn(out_root, manifest)
    with open(os.path.join(out_root, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(out_root, 'manifest.txt')} "
          f"({len(manifest)} records)")


if __name__ == "__main__":
    main()
