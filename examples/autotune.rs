//! Scenario: "I was just handed a cluster — what is the fastest way to
//! train my MLLM on it?" — the autotuner as a planning service.
//!
//! Sweeps device budgets for a VLM and a VALM, tuning each scenario
//! end-to-end (policy × encoder placement × LLM depth × TP/CP ×
//! frozen recipe), then shows the persistent plan cache answering the
//! same query again without simulating anything.
//!
//! ```bash
//! cargo run --release --example autotune
//! ```

use anyhow::Result;
use cornstarch::model::{MllmSpec, Size};
use cornstarch::tuner::{tune, FrozenSetting, TuneRequest};
use cornstarch::util::table::Table;

fn main() -> Result<()> {
    let mut cache_path = std::env::temp_dir();
    cache_path.push("cornstarch-autotune-example.json");
    let _ = std::fs::remove_file(&cache_path);
    let cache = cache_path.to_string_lossy().into_owned();

    let mut t = Table::new(
        "autotuned plans (objective: iteration time; cache: on)",
        &[
            "model", "GPUs", "best plan", "iter (ms)", "tput/GPU",
            "simulated", "pruned",
        ],
    );
    let scenarios: Vec<(MllmSpec, usize)> = vec![
        (MllmSpec::vlm(Size::M, Size::M), 8),
        (MllmSpec::vlm(Size::M, Size::M), 16),
        (MllmSpec::vlm(Size::M, Size::L), 16),
        (MllmSpec::valm(Size::M, Size::M, Size::M), 24),
    ];
    for (spec, devices) in &scenarios {
        let mut req = TuneRequest::new(spec.clone(), *devices);
        req.cache_path = Some(cache.clone());
        let out = tune(&req)?;
        let best = out.entry.best();
        t.row(&[
            spec.name(),
            devices.to_string(),
            best.candidate.label(),
            format!("{:.1}", best.iteration_ms),
            format!("{:.3}", best.throughput_per_gpu),
            out.evaluated.to_string(),
            out.pruned.to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- the cache makes the second pass O(1) ----
    let t0 = std::time::Instant::now();
    for (spec, devices) in &scenarios {
        let mut req = TuneRequest::new(spec.clone(), *devices);
        req.cache_path = Some(cache.clone());
        let out = tune(&req)?;
        assert!(out.cache_hit, "expected a cache hit on the second pass");
    }
    println!(
        "second pass over all {} scenarios: cache hits only, {:.1} ms total",
        scenarios.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // ---- frozen policy changes the answer ----
    let mut req = TuneRequest::new(MllmSpec::vlm(Size::M, Size::L), 16);
    req.space.frozen_choices = vec![FrozenSetting::AllTrainable];
    let full = tune(&req)?;
    req.space.frozen_choices = vec![FrozenSetting::Paper];
    let paper = tune(&req)?;
    println!(
        "\nVLM-L @16: paper recipe {:.1} ms vs full fine-tune {:.1} ms — \
         frozen-aware placement is why the tuner must know the policy",
        paper.entry.best().iteration_ms,
        full.entry.best().iteration_ms
    );

    // ---- the cached frontier answers trade-off queries for free ----
    // The first loop persisted a top-5 frontier for this exact scenario;
    // asking for the top 3 is served straight from the cache.
    let mut req = TuneRequest::new(MllmSpec::vlm(Size::M, Size::M), 16);
    req.top = 3;
    req.cache_path = Some(cache.clone());
    let out = tune(&req)?;
    assert!(out.cache_hit, "frontier query should be a cache hit");
    println!("\ntop-{} frontier (throughput vs GPUs vs headroom):", req.top);
    for (i, p) in out.entry.frontier.iter().enumerate() {
        println!(
            "  #{}: {:.1} ms | {} GPUs | peak {:.1} GB | {}",
            i + 1,
            p.iteration_ms,
            p.n_gpus,
            cornstarch::memory::gb(p.peak_mem_bytes),
            p.candidate.label()
        );
    }

    let _ = std::fs::remove_file(&cache_path);
    Ok(())
}
