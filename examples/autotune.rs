//! Scenario: "I was just handed a cluster — what is the fastest way to
//! train my MLLM on it?" — the planning service end-to-end.
//!
//! Sweeps device budgets for a VLM and a VALM through
//! `PlanningService::plan` (policy × encoder placement × LLM depth ×
//! TP/CP × microbatches × frozen recipe), shows the persistent plan
//! cache answering the same `PlanRequest` again without simulating
//! anything, and then swaps the `ClusterSpec` — same model, 80 GB
//! devices instead of 40 GB A40s — to show the hardware truth changing
//! the answer (OOM-pruned candidates readmitted).
//!
//! ```bash
//! cargo run --release --example autotune
//! ```

use anyhow::Result;
use cornstarch::api::{ClusterSpec, PlanRequest, PlanningService};
use cornstarch::memory;
use cornstarch::model::{MllmSpec, Size};
use cornstarch::tuner::FrozenSetting;
use cornstarch::util::table::Table;

fn main() -> Result<()> {
    let mut cache_path = std::env::temp_dir();
    cache_path.push("cornstarch-autotune-example.json");
    let _ = std::fs::remove_file(&cache_path);
    let cache = cache_path.to_string_lossy().into_owned();
    let service = PlanningService::new();

    let mut t = Table::new(
        "planning service (objective: iteration time; cache: on)",
        &[
            "model", "GPUs", "best plan", "iter (ms)", "tput/GPU",
            "simulated", "pruned",
        ],
    );
    let scenarios: Vec<(MllmSpec, usize)> = vec![
        (MllmSpec::vlm(Size::M, Size::M), 8),
        (MllmSpec::vlm(Size::M, Size::M), 16),
        (MllmSpec::vlm(Size::M, Size::L), 16),
        (MllmSpec::valm(Size::M, Size::M, Size::M), 24),
    ];
    let request = |spec: &MllmSpec, devices: usize| {
        PlanRequest::default_for(spec.clone())
            .devices(devices)
            .cache_file(&cache)
    };
    for (spec, devices) in &scenarios {
        let report = service.plan(&request(spec, *devices))?;
        let best = report.winner();
        t.row(&[
            spec.name(),
            devices.to_string(),
            best.candidate.label(),
            format!("{:.1}", best.iteration_ms),
            format!("{:.3}", best.throughput_per_gpu),
            report.provenance.evaluated.to_string(),
            report.provenance.pruned.to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- the cache makes the second pass O(1) ----
    let t0 = std::time::Instant::now();
    for (spec, devices) in &scenarios {
        let report = service.plan(&request(spec, *devices))?;
        assert!(
            report.provenance.cache_hit,
            "expected a cache hit on the second pass"
        );
    }
    println!(
        "second pass over all {} scenarios: cache hits only, {:.1} ms total",
        scenarios.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // ---- the cluster spec changes the answer ----
    // Same model and pool size; 80 GB devices instead of 40 GB A40s.
    // Candidates the A40's memory budget OOM-pruned are readmitted, so
    // the search sees a strictly larger space.
    let spec = MllmSpec::vlm(Size::M, Size::M);
    let a40 = service.plan(
        &PlanRequest::default_for(spec.clone()).devices(16),
    )?;
    let mut big = ClusterSpec::a40_default().with_devices(16);
    big.name = "a100ish-80g".to_string();
    big.groups[0].device.name = "A100-80G".to_string();
    big.groups[0].device.mem_bytes = 80_000_000_000;
    let roomy = service
        .plan(&PlanRequest::default_for(spec.clone()).cluster(big))?;
    println!(
        "\n{} @16 on 40 GB A40s: {} candidates, best {:.1} ms \
         (peak {:.1} GB/GPU)",
        spec.name(),
        a40.provenance.total_candidates,
        a40.winner().iteration_ms,
        memory::gb(a40.winner().peak_mem_bytes),
    );
    println!(
        "{} @16 on 80 GB devices: {} candidates ({} readmitted), best \
         {:.1} ms (peak {:.1} GB/GPU)",
        spec.name(),
        roomy.provenance.total_candidates,
        roomy
            .provenance
            .total_candidates
            .saturating_sub(a40.provenance.total_candidates),
        roomy.winner().iteration_ms,
        memory::gb(roomy.winner().peak_mem_bytes),
    );

    // ---- frozen policy changes the answer ----
    let base = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::L));
    let mut all_trainable = base.resolved_space();
    all_trainable.frozen_choices = vec![FrozenSetting::AllTrainable];
    let full = service.plan(&base.clone().space(all_trainable))?;
    let paper = service.plan(&base)?;
    println!(
        "\nVLM-L @16: paper recipe {:.1} ms vs full fine-tune {:.1} ms — \
         frozen-aware placement is why the planner must know the policy",
        paper.winner().iteration_ms,
        full.winner().iteration_ms
    );

    // ---- the cached frontier answers trade-off queries for free ----
    // The first loop persisted a top-5 frontier for this exact request;
    // asking for the top 3 is served straight from the cache.
    let req = request(&MllmSpec::vlm(Size::M, Size::M), 16).top(3);
    let report = service.plan(&req)?;
    assert!(
        report.provenance.cache_hit,
        "frontier query should be a cache hit"
    );
    println!("\ntop-3 frontier (throughput vs GPUs vs headroom):");
    for (i, p) in report.frontier.iter().take(3).enumerate() {
        println!(
            "  #{}: {:.1} ms | {} GPUs | peak {:.1} GB | {}",
            i + 1,
            p.iteration_ms,
            p.n_gpus,
            memory::gb(p.peak_mem_bytes),
            p.candidate.label()
        );
    }

    // ---- heterogeneous pools: placement is a search dimension ----
    // 4 cheap A40s + 4 big A100s: the tuner decides which device group
    // each pipeline chain lands on, so the frozen encoder rides the
    // 40 GB cards while the LLM claims the 80 GB ones.
    let hetero = service.plan(
        &PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::L))
            .cluster(ClusterSpec::a40_a100_demo()),
    )?;
    println!(
        "\nVLM-L on 4xA40 + 4xA100-80G: {} ({:.1} ms)",
        hetero.winner().candidate.label(),
        hetero.winner().iteration_ms
    );
    for v in &hetero.stage_verdicts {
        println!(
            "  {:<16} -> {:<10} {:>6.1} / {:.0} GB",
            v.stage,
            v.device,
            memory::gb(v.peak_bytes),
            memory::gb(v.budget_bytes)
        );
    }

    let _ = std::fs::remove_file(&cache_path);
    Ok(())
}
