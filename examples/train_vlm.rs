//! END-TO-END DRIVER (the mandated validation run): train a ~100M-param
//! vision-language model for a few hundred steps on synthetic multimodal
//! data, through the full three-layer stack — Pallas BAM-attention kernel
//! inside JAX-lowered HLO stage programs, executed by the rust
//! thread-per-stage pipeline coordinator over PJRT — and log the loss
//! curve. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! ARTIFACT_MODELS=e2e100m make artifacts   # exports the 100M-class model
//! cargo run --release --example train_vlm -- [steps] [microbatches]
//! ```
//!
//! Falls back to the `mini` (~35M) model if the 100M artifacts are not
//! built, so the example is always runnable after plain `make artifacts`.

use anyhow::Result;
use cornstarch::runtime::Manifest;
use cornstarch::train::{FrozenPolicy, PipelineTrainer, SyntheticDataset};
use cornstarch::util::json::Json;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let mbs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let lr: f32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1e-4);

    let manifest = Manifest::load(Manifest::default_root())?;
    let model_name = if manifest.model("e2e100m").is_ok() {
        "e2e100m"
    } else {
        eprintln!(
            "note: e2e100m artifacts not found — using `mini`. Build them \
             with: ARTIFACT_MODELS=e2e100m make artifacts"
        );
        "mini"
    };
    let model = manifest.model(model_name)?.clone();
    let total_params: usize = model
        .components
        .iter()
        .filter(|c| c.shares_params_with.is_none())
        .map(|c| c.n_params)
        .sum();
    println!(
        "model {model_name}: {:.1}M params, {} tokens/sample, {} components",
        total_params as f64 / 1e6,
        model.total_tokens,
        model.components.len()
    );

    // The paper's recipe: frozen encoder+LLM, trainable projector, would
    // plateau quickly at this scale; the e2e driver trains EVERYTHING so
    // the loss curve demonstrably learns the Markov text structure.
    let policy = FrozenPolicy::all_trainable();
    let mut trainer = PipelineTrainer::new(&manifest, model_name, policy, lr)?;
    println!(
        "pipeline: {} stage threads (encoders modality-parallel + LLM chain)",
        trainer.n_stages()
    );

    let ds = SyntheticDataset::new(&model, 2024);
    let mut losses = Vec::with_capacity(steps);
    let mut walls = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let batch: Vec<_> = (0..mbs)
            .map(|i| ds.sample((step * mbs + i) as u64))
            .collect();
        let s = trainer.train_step(&batch)?;
        losses.push(s.loss as f64);
        walls.push(s.wall_ms);
        if step < 5 || (step + 1) % 10 == 0 {
            println!(
                "step {:>4}/{steps}  loss {:.4}  {:>6.0} ms/step",
                step + 1,
                s.loss,
                s.wall_ms
            );
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    let samples = (steps * mbs) as f64;
    println!(
        "\n{} steps in {:.1}s — {:.2} samples/s, loss {:.4} -> {:.4}",
        steps,
        total_s,
        samples / total_s,
        losses.first().unwrap(),
        losses.last().unwrap()
    );
    let head = losses.iter().take(10).sum::<f64>() / 10f64.min(steps as f64);
    let tail = losses.iter().rev().take(10).sum::<f64>() / 10f64.min(steps as f64);
    println!("mean(first 10) {head:.4} -> mean(last 10) {tail:.4}");
    anyhow::ensure!(
        tail < head,
        "loss did not decrease ({head:.4} -> {tail:.4})"
    );

    let out = format!("{model_name}_loss.json");
    std::fs::write(
        &out,
        Json::obj(vec![
            ("model", Json::Str(model_name.to_string())),
            ("params", Json::Int(total_params as i64)),
            ("steps", Json::Int(steps as i64)),
            ("microbatches", Json::Int(mbs as i64)),
            ("loss", Json::arr_f64(&losses)),
            ("wall_ms", Json::arr_f64(&walls)),
        ])
        .render(),
    )?;
    println!("loss curve written to {out}");
    Ok(())
}
