//! Scenario: cluster capacity planning for an MLLM training job — the
//! §1 motivation ("healthcare: medical images + patient records; robotics:
//! visual + auditory inputs") expressed as a planning question: *given 24
//! GPUs, which parallelization should I use for my model, and what does
//! each policy cost me?*
//!
//! Sweeps every Table-1 composition through the three policies plus
//! Algorithm 1's automatic search and prints a recommendation.
//!
//! ```bash
//! cargo run --release --example capacity_planner
//! ```

use cornstarch::cost::Device;
use cornstarch::modality::{
    auto_parallelize, planner, MultimodalModule, MultimodalParallelSpec,
    Strategy,
};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::util::table::Table;

fn main() {
    let device = Device::a40();
    let mut t = Table::new(
        "24-GPU capacity plan (tp=2, cp=2 -> 6 device groups), input/s/GPU",
        &[
            "model", "replicated", "colocated", "cornstarch (auto)",
            "auto config (llm|encs)", "gain",
        ],
    );

    let mut specs: Vec<MllmSpec> = Vec::new();
    for e in Size::ALL {
        specs.push(MllmSpec::vlm(Size::M, e));
        specs.push(MllmSpec::alm(Size::M, e));
    }
    for v in Size::ALL {
        for a in Size::ALL {
            specs.push(MllmSpec::valm(Size::M, v, a));
        }
    }

    for spec in &specs {
        let mm = MultimodalModule::from_spec(spec);
        let n_enc = mm.encoders.len();
        // Encoders-colocated, tuned the way its users tune it (§2.2): pick
        // the stage split that best balances *forward* time between the
        // encoder stages and the LLM stages ("bwd = 2x fwd" assumed).
        let enc_fwd: f64 = mm
            .encoders
            .iter()
            .map(|e| e.layer_fwd_ms(device, 4) * e.geom.n_layers as f64)
            .sum();
        let llm_fwd =
            mm.llm.layer_fwd_ms(device, 4) * mm.llm.geom.n_layers as f64;
        let mut best_split = (1usize, 5usize);
        let mut best_gap = f64::INFINITY;
        for enc_pp in 1..=5usize {
            let llm_pp = 6 - enc_pp;
            let gap =
                (enc_fwd / enc_pp as f64 - llm_fwd / llm_pp as f64).abs();
            if gap < best_gap {
                best_gap = gap;
                best_split = (enc_pp, llm_pp);
            }
        }
        let col = {
            let ps = MultimodalParallelSpec::paper_default(
                &vec![best_split.0; n_enc],
                best_split.1,
                2,
                2,
            );
            planner::plan(Strategy::Colocated, &mm, &ps, device)
                .simulate()
                .throughput_per_gpu
        };
        // Encoders-replicated always uses 6 LLM stages (paper §B.1).
        let rep = {
            let ps = MultimodalParallelSpec::paper_default(
                &vec![1; n_enc],
                6,
                2,
                2,
            );
            planner::plan(Strategy::Replicated, &mm, &ps, device)
                .simulate()
                .throughput_per_gpu
        };
        // Cornstarch via Algorithm 1; select the frontier point with the
        // best per-GPU throughput (the capacity-planning objective).
        let auto = auto_parallelize(&mm, 6, 2, 2, 6, device);
        let (llm_pp, enc_pps, _, cs) = auto
            .frontier
            .iter()
            .max_by(|a, b| a.3.partial_cmp(&b.3).unwrap())
            .unwrap()
            .clone();
        let (best_col, best_rep) = (col, rep);
        let gain = cs / best_col.max(best_rep);
        t.row(&[
            spec.name(),
            format!("{best_rep:.2}"),
            format!("{best_col:.2}"),
            format!("{cs:.2}"),
            format!("{llm_pp} | {enc_pps:?}"),
            format!("{gain:.2}x"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Reading the table: `gain` > 1 means modality parallelism + \
         frozen-aware partitioning beats the best hand-tuned baseline; the \
         advantage grows with encoder size (the paper's §6.2 observation)."
    );
}
