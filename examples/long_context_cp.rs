//! Scenario: long-context multimodal context parallelism — a "video
//! assistant" sample: a long transcript with interleaved frame and audio
//! segments (EE layout) packed with a second short sample (MP layout),
//! distributed across 8 CP ranks.
//!
//! Shows the full §4.3 pipeline: BAM construction (never materializing
//! the [T,T] mask), per-token workloads, the four distribution
//! algorithms' balance, the predicted attention step time — and then runs
//! the REAL Pallas BAM-attention artifact through PJRT on the same mask
//! shape (at the artifact's T) to demonstrate the kernel consumes exactly
//! this representation.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example long_context_cp
//! ```

use anyhow::Result;
use cornstarch::bam::{self, Bam};
use cornstarch::coordinator::experiments::cp_step_ms;
use cornstarch::cp::metrics::AttnTimeModel;
use cornstarch::cp::{rank_loads, Algorithm};
use cornstarch::runtime::{AttnRuntime, Manifest};
use cornstarch::util::rng::Rng;
use cornstarch::util::table::Table;

fn main() -> Result<()> {
    // ---- build the scenario mask: 64k tokens ----
    // sample 1: transcript with 6 video-frame segments and 3 audio segments
    // interleaved (EE); sample 2: a short packed Q&A (MP packing).
    let frames = 3000usize;
    let audio = 1500usize;
    let seg_lens = vec![frames, audio, frames, audio, frames, audio, frames];
    let text_runs = vec![4000, 6000, 6000, 6000, 6000, 6000, 5000, 2000];
    // MP: pack sample 1 (EE-structured) with a small text-only sample 2.
    let s1_text: usize = text_runs.iter().sum();
    let s1_mod: usize = seg_lens.iter().sum();
    let mask = bam::generators::mp(&[
        (s1_text + s1_mod - s1_mod, seg_lens.clone()), // sample 1
        (4096, vec![512]),                             // sample 2
    ]);
    let t = mask.len();
    println!(
        "scenario mask: {t} tokens, {} bytes as BAM vs {:.1} GB as a \
         full [T,T] bool mask",
        t * 8,
        (t as f64) * (t as f64) / 1e9
    );

    // ---- workloads + distribution ----
    let g = 8;
    let model = AttnTimeModel::llama70b_a40();
    let mut table = Table::new(
        "distribution balance, 8 CP ranks",
        &["algorithm", "rank loads (Mpairs)", "imbalance", "step (ms)"],
    );
    for alg in [
        Algorithm::Lpt,
        Algorithm::Random { seed: 1 },
        Algorithm::Ring,
        Algorithm::Zigzag,
    ] {
        let blk = if matches!(alg, Algorithm::Random { .. }) { 1 } else { 128 };
        let w = bam::block_workloads(&mask.workloads(), blk);
        let assign = alg.assign(&w, g);
        let loads = rank_loads(&w, &assign, g);
        let lf: Vec<f64> = loads.iter().map(|&l| l as f64).collect();
        let imb = cornstarch::util::stats::imbalance(&lf);
        let ms = cp_step_ms(&mask, &alg, g, 128, &model);
        table.row(&[
            alg.name().to_string(),
            loads
                .iter()
                .map(|l| format!("{:.0}", *l as f64 / 1e6))
                .collect::<Vec<_>>()
                .join(" "),
            format!("{imb:.3}"),
            format!("{ms:.2}"),
        ]);
    }
    println!("{}", table.render());

    // ---- the same representation drives the real kernel ----
    let manifest = Manifest::load(Manifest::default_root())?;
    let rt = AttnRuntime::load(&manifest, "attn512")?;
    let kt = rt.spec.tokens;
    // shrink the scenario to the artifact's T, preserving structure
    let scale = |x: usize| (x * kt / t).max(1);
    let mini = bam::generators::mp(&[
        (
            text_runs.iter().map(|&x| scale(x)).sum::<usize>(),
            seg_lens.iter().map(|&x| scale(x)).collect(),
        ),
        (scale(4096), vec![scale(512)]),
    ]);
    let mut bits = mini.bits.clone();
    bits.resize(kt, *bits.last().unwrap());
    let mini = Bam::new(bits, mini.text_mask);
    let n = kt * rt.spec.heads * rt.spec.head_dim;
    let mut rng = Rng::new(8);
    let mk = |rng: &mut Rng| -> Vec<f32> {
        (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect()
    };
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let (out, ms) = rt.run(&q, &k, &v, &mini.bits_i32(), &mini.pos_i32())?;
    println!(
        "real PJRT BAM attention on the scaled mask (T={kt}): {ms:.1} ms, \
         output[0..4] = {:?}",
        &out[..4]
    );
    println!(
        "(interpret-mode Pallas on CPU — structure identical to the TPU \
         kernel; see DESIGN.md §Hardware-Adaptation)"
    );
    Ok(())
}
