//! Quickstart: the paper's Listing 1, in rust.
//!
//! Builds an MLLM from unimodal modules, applies a
//! `MultimodalParallelSpec`, inspects the resulting pipeline plan, and
//! then runs a few REAL training steps on the `tiny` artifact model
//! through PJRT (the L3 hot path — python never runs here).
//!
//! ```bash
//! make artifacts            # once: python AOT-compiles the HLO programs
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use cornstarch::cost::Device;
use cornstarch::modality::{
    ModalityModule, MultimodalModule, MultimodalParallelSpec, ParallelSpec,
};
use cornstarch::model::{eva_clip, llama, whisper, Size, TokenCounts};
use cornstarch::runtime::Manifest;
use cornstarch::train::{FrozenPolicy, PipelineTrainer, SyntheticDataset};

fn main() -> Result<()> {
    // ---- Listing 1, lines 8-22: load unimodal models, glue an MLLM ----
    let tok = TokenCounts::paper();
    let vis = ModalityModule::encoder("vision", eva_clip(Size::M), tok.vision);
    let aud = ModalityModule::encoder("audio", whisper(Size::M), tok.audio);
    let llm = ModalityModule::llm(llama(Size::M), tok.llm_total(true, true));
    let mut mllm = MultimodalModule::new(vec![vis, aud], llm);

    // ---- lines 24-26: set frozen status (the §6.1 recipe) ----
    mllm.encoders[0].train(false); // frozen encoder
    mllm.encoders[0].projector_trainable = true; // trainable projector
    mllm.llm.train(false);

    // ---- lines 29-42: parallelize ----
    let spec = MultimodalParallelSpec {
        encoder_specs: vec![
            ParallelSpec::new(2, 2, 1), // vision: tp=2, cp=2, pp=1
            ParallelSpec::new(2, 2, 1), // audio
        ],
        llm_spec: ParallelSpec::new(2, 2, 4),
        num_microbatches: 24,
        comm_ms: 0.5,
        grad_ckpt: true,
    };
    let plan = spec.apply(&mllm);
    println!("== parallel plan (modality parallelism + frozen-aware PP) ==");
    for (name, node) in plan.stage_names.iter().zip(&plan.graph.nodes) {
        println!(
            "  {:<14} device-group {:<2} fwd {:>7.1} ms  bwd {:>7.1} ms",
            name, node.device, node.cost.fwd_ms, node.cost.bwd_ms
        );
    }
    let m = plan.simulate();
    println!(
        "  iteration {:.0} ms, {:.2} input/s, {:.3} input/s/GPU on {} GPUs\n",
        m.iteration_ms, m.throughput, m.throughput_per_gpu, plan.n_gpus
    );

    // Contrast with Algorithm 1's automatic search:
    let auto = cornstarch::modality::auto_parallelize(
        &mllm,
        6,
        2,
        2,
        6,
        Device::a40(),
    );
    println!(
        "Algorithm 1 would pick llm_pp={} enc_pps={:?} ({:.0} ms/iter)\n",
        auto.frontier
            .iter()
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap()
            .0,
        auto.frontier
            .iter()
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap()
            .1,
        auto.best_metrics.iteration_ms
    );

    // ---- lines 44-48: execute — real PJRT training on the tiny model ----
    // The planning half above needs nothing but this crate; the training
    // half executes AOT artifacts through PJRT. Without them (CI smoke
    // runs, fresh checkouts) stop here instead of erroring.
    let root = Manifest::default_root();
    if !root.join("manifest.txt").exists() {
        println!(
            "no artifacts at {} — skipping the PJRT training demo \
             (run `make artifacts` first)",
            root.display()
        );
        return Ok(());
    }
    let manifest = Manifest::load(root)?;
    let mut trainer =
        PipelineTrainer::new(&manifest, "tiny", FrozenPolicy::paper(), 3e-3)?;
    let model = manifest.model("tiny")?.clone();
    let ds = SyntheticDataset::new(&model, 42);
    println!(
        "== real training (tiny model, {} pipeline stage threads) ==",
        trainer.n_stages()
    );
    for step in 0..5 {
        let batch: Vec<_> =
            (0..4).map(|i| ds.sample((step * 4 + i) as u64)).collect();
        let s = trainer.train_step(&batch)?;
        println!(
            "  step {}  loss {:.4}  ({:.0} ms)",
            s.step, s.loss, s.wall_ms
        );
    }
    println!("done — see examples/train_vlm.rs for the ~100M e2e run");
    Ok(())
}
