#!/usr/bin/env python3
"""Source lint: raw `println!` / `eprintln!` are reserved for the
telemetry sink (`rust/src/telemetry/mod.rs`) — everything else must
route user-facing output through `telemetry::report` / `log` so the
`--quiet` / `-v` contract and trace capture keep working. Examples and
tests are designated report-output sites and are not scanned.

Exit status: 0 clean, 1 when a raw print site is found.
"""

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = ROOT / "rust" / "src"
ALLOWED = {SRC / "telemetry" / "mod.rs"}


def main() -> int:
    scanned = 0
    bad = []
    for path in sorted(SRC.rglob("*.rs")):
        if path in ALLOWED:
            continue
        scanned += 1
        for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), start=1
        ):
            stripped = line.lstrip()
            # Comment lines (incl. `///` doc examples) may show prints.
            if stripped.startswith("//"):
                continue
            if "println!" in stripped or "eprintln!" in stripped:
                rel = path.relative_to(ROOT)
                bad.append(f"{rel}:{lineno}: {stripped}")
    if bad:
        print(
            "raw print sites found — route output through "
            "telemetry::report / telemetry::log:"
        )
        for entry in bad:
            print(f"  {entry}")
        return 1
    print(f"println lint: clean ({scanned} files scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
